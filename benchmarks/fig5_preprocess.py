"""Figure 5 analogue: quantization preprocessing applied to OTHER
methods (GPTQ-2 / PB-LLM / BiLLM) — the paper's transferability claim."""
from __future__ import annotations

from benchmarks.common import (get_trained_tiny, markdown_table,
                               perplexity, quantize, write_result)

METHODS = ["gptq-2", "pbllm", "billm"]


def run(quick: bool = False) -> dict:
    cfg, params, corpus = get_trained_tiny()
    methods = ["pbllm"] if quick else METHODS
    rows = []
    for m in methods:
        for pre in (False, True):
            qp = quantize(m, cfg, params, corpus, preprocess=pre)
            rows.append({
                "method": m, "preprocessed": pre,
                "ppl_valid": perplexity(cfg, qp, corpus, split="valid"),
            })
            print(f"[fig5] {m:8s} pre={pre} "
                  f"ppl={rows[-1]['ppl_valid']:.2f}")
    payload = {"rows": rows}
    write_result("fig5_preprocess", payload)
    print(markdown_table(rows, ["method", "preprocessed", "ppl_valid"]))
    return payload


if __name__ == "__main__":
    run()
