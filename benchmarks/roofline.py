"""§Roofline reader: aggregate results/dryrun/*.json into the per-(arch ×
cell × mesh) roofline table that EXPERIMENTS.md embeds."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import markdown_table, write_result

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh: str = "pod", tag: str = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, mesh, "*.json"))):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        if (len(parts) == 2) != (tag == ""):
            continue
        if tag and (len(parts) < 3 or parts[2] != tag):
            continue
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "cell": rec["cell"],
                         "status": "skipped"})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "cell": rec["cell"],
                         "status": "ERROR"})
            continue
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "cell": rec["cell"], "status": "ok",
            "compute_ms": r["compute_s"] * 1e3,
            "memory_ms": r["memory_s"] * 1e3,
            "collective_ms": r["collective_s"] * 1e3,
            "dominant": r["dominant"],
            "compute_frac": r["compute_fraction"],
            "useful_flops": rec.get("useful_flops_ratio", 0.0),
            "quantized": rec.get("quantized_serving", False),
        })
    return rows


def run(quick: bool = False) -> dict:
    out = {}
    for mesh in ("pod", "multipod"):
        rows = load(mesh)
        if not rows:
            continue
        out[mesh] = rows
        print(f"\n=== roofline: {mesh} ===")
        print(markdown_table(
            [r for r in rows if r["status"] == "ok"],
            ["arch", "cell", "compute_ms", "memory_ms", "collective_ms",
             "dominant", "compute_frac", "useful_flops"]))
        n_err = sum(r["status"] == "ERROR" for r in rows)
        if n_err:
            print(f"!! {n_err} ERROR cells in {mesh}")
    write_result("roofline_table", out)
    return out


if __name__ == "__main__":
    run()
