"""Table 3 analogue: component ablation on the tiny subject.

Paper rows (LLaMA-13B):       ours (tiny-lm, same toggles):
  none              14664       binarize-only (no mask, analytic α)
  +mask              1370       structured mask only
  preprocess-only     570       preprocess, then binarize-only
  +mask+learn        14.2       mask + block-wise learned scales
  full                9.7       mask + learn + preprocess

The validated claim is the ORDERING (each component helps, learnable
scales are the big step), not the absolute numbers.
"""
from __future__ import annotations

from benchmarks.common import (get_trained_tiny, markdown_table,
                               perplexity, quantize, write_result)

ROWS = [
    ("none", dict(use_mask=False, learn_scales=False), False),
    ("mask", dict(use_mask=True, learn_scales=False), False),
    ("preprocess", dict(use_mask=False, learn_scales=False), True),
    ("mask+learn", dict(use_mask=True, learn_scales=True), False),
    ("full", dict(use_mask=True, learn_scales=True), True),
]


def run(quick: bool = False) -> dict:
    cfg, params, corpus = get_trained_tiny()
    fp_ppl = perplexity(cfg, params, corpus)
    rows = [{"config": "fp16", "ppl_valid": fp_ppl, "ppl_calib":
             perplexity(cfg, params, corpus, split="calib")}]
    for name, overrides, pre in ROWS:
        qp = quantize("ptq161", cfg, params, corpus, preprocess=pre,
                      qcfg_overrides=overrides)
        row = {"config": name,
               "ppl_valid": perplexity(cfg, qp, corpus, split="valid"),
               "ppl_calib": perplexity(cfg, qp, corpus, split="calib")}
        rows.append(row)
        print(f"[table3] {name:12s} ppl={row['ppl_valid']:.2f}")
    payload = {"rows": rows}
    write_result("table3_ablation", payload)
    print(markdown_table(rows, ["config", "ppl_valid", "ppl_calib"]))
    return payload


if __name__ == "__main__":
    run()
