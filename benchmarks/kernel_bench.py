"""Appendix E.3 analogue: kernel-level weight-traffic accounting.

No TPU here, so instead of wall time we report the HBM bytes each kernel
streams per (M,K,N) matmul — the quantity that determines decode
throughput on a bandwidth-bound chip — plus the modeled v5e time for
bf16 vs int4 vs PTQ1.61-mixed layouts, and a CPU interpret-mode
correctness spot check.  (BitNet's measured 2.9×–8.9× speedups at
1.58-bit are the wall-clock analogue of the same ratio — App. E.3.)

Decode fast path rows (`fused_block`): a LLaMA-7B-shaped transformer
block served at decode batch M ∈ {1, 4, 16, 32}, comparing the N-FUSED
layout (one QKV call + one gate-up call, one activation gather each,
autotuned blocks) against per-projection calls (5 calls, 5 gathers).
Packed WEIGHT bytes are identical by construction — fusion's win is the
per-call overhead traffic (activation gather + (M,K) tile reads + f32
scale vectors), reported as ``act_bytes`` with the reduction ratio in
``act_reduction`` (the PR's ≥1.5× acceptance bar); ``total_mb`` keeps
the weight-dominated totals honest next to it.
"""
from __future__ import annotations

import argparse

import numpy as np
import jax.numpy as jnp

from benchmarks.common import markdown_table, write_result
from repro.core.saliency import round_salient
from repro.kernels import autotune
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS

SHAPES = [(1, 4096, 4096), (16, 4096, 4096), (1, 4096, 11008),
          (256, 8192, 8192)]

# LLaMA-7B block projections: (name, K, N)
D_MODEL, D_FF = 4096, 11008
BLOCK_PROJ = [("wq", D_MODEL, D_MODEL), ("wk", D_MODEL, D_MODEL),
              ("wv", D_MODEL, D_MODEL), ("wg", D_MODEL, D_FF),
              ("wu", D_MODEL, D_FF)]
BLOCK_FUSED = [("wqkv", D_MODEL, 3 * D_MODEL), ("wgu", D_MODEL, 2 * D_FF)]
DECODE_MS = (1, 4, 16, 32)
RATIO, MULTIPLE = 0.2, 128


def layout_bytes(kind: str, m: int, k: int, n: int) -> float:
    """Weight + activation HBM bytes per matmul call."""
    act = (m * k + m * n) * 2                      # bf16 in/out
    if kind == "bf16":
        return act + k * n * 2
    if kind == "int4":
        return act + k * n / 2 + k * 4 * 2
    if kind == "ptq161":                           # 20% int4, 80% binary
        k_s = int(0.2 * k)
        k_b = k - k_s
        return (act + k_s * n / 2 + k_b * n / 8
                + (2 * n + k_b + 2 * k_s) * 2)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Decode-shaped fused-vs-unfused traffic model
# ---------------------------------------------------------------------------
def call_traffic(m: int, k: int, n: int) -> dict:
    """Modeled HBM bytes for ONE autotuned mixed_matmul call, split into
    weight / overhead (gather + x reads + scale vectors) / output.

    The kernel-side bytes come from ``choice.hbm_bytes`` — the SAME
    ``autotune.modeled_hbm_bytes`` the tuner minimizes — so this table
    cannot drift from the model the block picks actually optimize; only
    the pre-kernel activation gather is added on top."""
    k_s = round_salient(k, RATIO, MULTIPLE)
    k_b = k - k_s
    choice = autotune.choose_blocks(m, k_s, k_b, n)
    assert choice is not None, (m, k_s, k_b, n)
    weight = autotune.weight_bytes(k_s, k_b, n) * -(-m // choice.bm)
    out = m * n * 4
    gather = 2 * m * k * 2                # read x + write permuted copy
    return {"weight": weight,
            "act": gather + choice.hbm_bytes - weight - out,
            "out": out, "blocks": (choice.bm, choice.bn, choice.bk)}


def fused_block_rows(ms=DECODE_MS) -> list:
    rows = []
    for m in ms:
        unf = [call_traffic(m, k, n) for _, k, n in BLOCK_PROJ]
        fus = [call_traffic(m, k, n) for _, k, n in BLOCK_FUSED]
        agg = lambda cs, f: sum(c[f] for c in cs)
        u_act, f_act = agg(unf, "act"), agg(fus, "act")
        u_tot = u_act + agg(unf, "weight") + agg(unf, "out")
        f_tot = f_act + agg(fus, "weight") + agg(fus, "out")
        rows.append({
            "m": m,
            "calls_unfused": len(unf), "calls_fused": len(fus),
            "weight_mb": agg(fus, "weight") / 1e6,     # identical both ways
            "act_kb_unfused": u_act / 1e3,
            "act_kb_fused": f_act / 1e3,
            "act_reduction": u_act / f_act,
            "total_mb_unfused": u_tot / 1e6,
            "total_mb_fused": f_tot / 1e6,
            "total_reduction": u_tot / f_tot,
        })
    return rows


def fused_spot_check() -> dict:
    """Interpret-mode correctness of the fused packed layout: the fused
    group's kernel forward vs its unfused members' XLA forwards."""
    import dataclasses
    from repro.core.qlinear import QuantConfig, quantize_linear_group

    rng = np.random.default_rng(0)
    k, n1, n2 = 640, 128, 256
    ws = [jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
          for n in (n1, n2)]
    stat = jnp.asarray(rng.uniform(0.1, 10.0, k), jnp.float32)
    g = quantize_linear_group(
        ws, stat, QuantConfig(ratio=RATIO, multiple=128, use_kernel=True))
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.bfloat16)
    y_fused = g.split_out(g.__matmul_x__(x))
    max_err = 0.0
    for y, member in zip(y_fused, g.members()):
        oracle = dataclasses.replace(
            member, use_kernel=False).__matmul_x__(x)
        max_err = max(max_err, float(np.max(np.abs(
            np.asarray(y, np.float32) - np.asarray(oracle, np.float32)))))
    tol = 0.06 * float(np.sqrt(k)) * 2     # test_kernels.py tolerance
    return {"shape": f"4x{k}x({n1}+{n2})", "max_abs_err": max_err,
            "tol": tol, "ok": max_err < tol}


def run(quick: bool = False) -> dict:
    rows = []
    for m, k, n in (SHAPES[:2] if quick else SHAPES):
        flops = 2 * m * k * n
        t_mxu = flops / PEAK_FLOPS
        for kind in ("bf16", "int4", "ptq161"):
            b = layout_bytes(kind, m, k, n)
            t_hbm = b / HBM_BW
            rows.append({
                "shape": f"{m}x{k}x{n}", "layout": kind,
                "weight_mb": (b - (m * k + m * n) * 2) / 1e6,
                "t_model_us": max(t_mxu, t_hbm) * 1e6,
                "bound": "compute" if t_mxu > t_hbm else "memory",
            })
    base = {r["shape"]: r["t_model_us"] for r in rows
            if r["layout"] == "bf16"}
    for r in rows:
        r["speedup_vs_bf16"] = base[r["shape"]] / r["t_model_us"]

    fb_rows = fused_block_rows(DECODE_MS[:2] if quick else DECODE_MS)
    spot = fused_spot_check()
    payload = {
        "rows": rows,
        "fused_block": {
            "projections": [p[0] for p in BLOCK_PROJ],
            "fused": [p[0] for p in BLOCK_FUSED],
            "d_model": D_MODEL, "d_ff": D_FF,
            "ratio": RATIO, "multiple": MULTIPLE,
            "note": ("act_bytes = activation gather + (M,K) tile reads + "
                     "f32 scale vectors; packed weight bytes are identical "
                     "fused vs unfused, so act_reduction is the fusion win "
                     "on the decode hot loop"),
            "rows": fb_rows,
            "min_act_reduction": min(r["act_reduction"] for r in fb_rows),
        },
        "fused_spot_check": spot,
        "autotuner_cache": str(autotune.cache_info()),
    }
    write_result("kernel_bench", payload)
    print(markdown_table(rows, ["shape", "layout", "weight_mb",
                                "t_model_us", "bound",
                                "speedup_vs_bf16"]))
    print("\nDecode fast path — fused QKV/gate-up block vs per-projection "
          "calls (modeled, autotuned blocks):")
    print(markdown_table(fb_rows, ["m", "calls_unfused", "calls_fused",
                                   "weight_mb", "act_kb_unfused",
                                   "act_kb_fused", "act_reduction",
                                   "total_mb_fused"]))
    print(f"\nfused layout interpret spot check: ok={spot['ok']} "
          f"max_abs_err={spot['max_abs_err']:.4f} (tol {spot['tol']:.3f})")
    assert spot["ok"], "fused layout kernel diverged from unfused oracle"
    min_red = payload["fused_block"]["min_act_reduction"]
    assert min_red >= 1.5, (
        f"fused block act-traffic reduction regressed to {min_red:.2f}x "
        f"(acceptance bar: >=1.5x at every decode M)")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced shape set (CI budget)")
    run(quick=ap.parse_args().quick)
