"""Appendix E.3 analogue: kernel-level weight-traffic accounting.

No TPU here, so instead of wall time we report the HBM weight bytes each
kernel streams per (M,K,N) matmul — the quantity that determines decode
throughput on a bandwidth-bound chip — plus the modeled v5e time for
bf16 vs int4 vs PTQ1.61-mixed layouts, and a CPU interpret-mode
correctness spot check.  (BitNet's measured 2.9×–8.9× speedups at
1.58-bit are the wall-clock analogue of the same ratio — App. E.3.)"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import markdown_table, write_result
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS

SHAPES = [(1, 4096, 4096), (16, 4096, 4096), (1, 4096, 11008),
          (256, 8192, 8192)]


def layout_bytes(kind: str, m: int, k: int, n: int) -> float:
    """Weight + activation HBM bytes per matmul call."""
    act = (m * k + m * n) * 2                      # bf16 in/out
    if kind == "bf16":
        return act + k * n * 2
    if kind == "int4":
        return act + k * n / 2 + k * 4 * 2
    if kind == "ptq161":                           # 20% int4, 80% binary
        k_s = int(0.2 * k)
        k_b = k - k_s
        return (act + k_s * n / 2 + k_b * n / 8
                + (2 * n + k_b + 2 * k_s) * 2)
    raise ValueError(kind)


def run(quick: bool = False) -> dict:
    rows = []
    for m, k, n in (SHAPES[:2] if quick else SHAPES):
        flops = 2 * m * k * n
        t_mxu = flops / PEAK_FLOPS
        for kind in ("bf16", "int4", "ptq161"):
            b = layout_bytes(kind, m, k, n)
            t_hbm = b / HBM_BW
            rows.append({
                "shape": f"{m}x{k}x{n}", "layout": kind,
                "weight_mb": (b - (m * k + m * n) * 2) / 1e6,
                "t_model_us": max(t_mxu, t_hbm) * 1e6,
                "bound": "compute" if t_mxu > t_hbm else "memory",
            })
    base = {r["shape"]: r["t_model_us"] for r in rows
            if r["layout"] == "bf16"}
    for r in rows:
        r["speedup_vs_bf16"] = base[r["shape"]] / r["t_model_us"]
    payload = {"rows": rows}
    write_result("kernel_bench", payload)
    print(markdown_table(rows, ["shape", "layout", "weight_mb",
                                "t_model_us", "bound",
                                "speedup_vs_bf16"]))
    return payload


if __name__ == "__main__":
    run()
