"""Appendix A: closed-form AND measured bits/weight.

Validates b = 1.6 + 0.0002 + 0.008 ≈ 1.61 at the paper's 4096² example,
measures the same on real packed QLinears across shapes, and reproduces
the PB-LLM (2.7) / BiLLM (2.1) comparison."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import markdown_table, write_result
from repro.core.baselines.driver import method_bits
from repro.core.bits import paper_closed_form, qlinear_bits
from repro.core.qlinear import QuantConfig, quantize_linear

SHAPES = [(1024, 1024), (4096, 4096), (4096, 11008), (8192, 1024)]


def run(quick: bool = False) -> dict:
    rows = []
    ref = paper_closed_form(4096, 4096, 0.2)
    rows.append({"case": "paper closed form 4096²",
                 "weight": ref.weight_bits, "index": ref.index_bits,
                 "extra": ref.additional_bits, "total": ref.total_bits})
    rng = np.random.default_rng(0)
    for k, n in (SHAPES[:2] if quick else SHAPES):
        w = jnp.asarray(rng.normal(size=(k, n)) * 0.02, jnp.float32)
        q = quantize_linear(w, None, QuantConfig(ratio=0.2, multiple=128))
        r = qlinear_bits(q)
        # measured = actual packed bytes (mask replaces stored perm)
        packed_bits = 8.0 * (q.packed_bytes() - q.perm.size * 4) + k
        rows.append({"case": f"measured {k}x{n}",
                     "weight": r.weight_bits, "index": r.index_bits,
                     "extra": r.additional_bits, "total": r.total_bits,
                     "packed_total": packed_bits / (k * n)})
    rows.append({"case": "PB-LLM (App. A)", "total": method_bits("pbllm")})
    rows.append({"case": "BiLLM (App. A)", "total": method_bits("billm")})
    payload = {"rows": rows}
    write_result("bits_accounting", payload)
    print(markdown_table(rows, ["case", "weight", "index", "extra",
                                "total"]))
    assert 1.60 < ref.total_bits < 1.62
    return payload


if __name__ == "__main__":
    run()
