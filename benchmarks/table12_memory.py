"""Table 12 analogue: serving-time weight memory per method, computed
from the App.-A bit accounting over the FULL assigned-architecture
parameter inventories (no allocation — closed form over declared shapes).

Paper numbers: LLaMA-7B PB-LLM 2.36GB / BiLLM 1.83GB / PTQ1.61 1.41GB."""
from __future__ import annotations

import numpy as np

from benchmarks.common import markdown_table, write_result
from repro.configs import registry
from repro.core.baselines.driver import method_bits
from repro.core.bits import paper_closed_form
from repro.core.select import is_quantizable
from repro.models import model as M
from repro.models.common import Parallel

ARCHS = ["llama-7b", "qwen3-4b", "command-r-35b", "mixtral-8x22b"]
METHODS = ["fp16", "pbllm", "billm", "ptq161"]


def weight_inventory(cfg):
    """(quantizable weights, exempt params) from the declared tree."""
    import jax
    decl = M.declare_params(cfg, Parallel())
    from repro.models.param import is_leaf
    q = exempt = 0
    qk = []

    def visit(path, leaf):
        nonlocal q, exempt
        n = int(np.prod(leaf.shape))
        if is_quantizable(path, leaf, 256):
            q += n
            qk.append(leaf.shape[-2:])
        else:
            exempt += n
        return leaf
    jax.tree_util.tree_map_with_path(visit, decl, is_leaf=is_leaf)
    return q, exempt, qk


def run(quick: bool = False) -> dict:
    rows = []
    for arch in (ARCHS[:2] if quick else ARCHS):
        cfg = registry.get(arch)
        q, exempt, shapes = weight_inventory(cfg)
        k, n = shapes[len(shapes) // 2]
        for m in METHODS:
            if m == "fp16":
                bits = 16.0
            elif m == "ptq161":
                bits = paper_closed_form(k, n, 0.2).total_bits
            else:
                bits = method_bits(m, k, n)
            gb = (q * bits / 8 + exempt * 2) / 1e9
            rows.append({"arch": arch, "method": m, "bits": bits,
                         "weight_gb": gb})
        print(f"[table12] {arch}: " + ", ".join(
            f"{r['method']}={r['weight_gb']:.2f}GB"
            for r in rows[-len(METHODS):]))
    payload = {"rows": rows}
    write_result("table12_memory", payload)
    print(markdown_table(rows, ["arch", "method", "bits", "weight_gb"]))
    return payload


if __name__ == "__main__":
    run()
