"""Shared benchmark substrate.

The paper's experiments quantize pretrained LLaMA/OPT checkpoints and
measure WikiText2/C4 perplexity.  Offline substitute (DESIGN.md §8):
train the in-repo `tiny-lm` subject (~3M params) on the deterministic
synthetic corpus to convergence once (cached under results/bench/), then
run every paper table against it.  Deltas are meaningful because the
corpus has real bigram structure: a collapsed model regresses to unigram
entropy, a good model approaches the bigram ceiling.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.configs import registry
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import model as M
from repro.models.common import Parallel

Tree = Any
PAR = Parallel(remat=False, attn_chunk=1024)
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
TRAIN_STEPS = 900
BATCH, SEQ = 8, 128
# paper protocol scaled to the tiny subject: 32 segments × 256 tokens
CALIB_SEGMENTS, CALIB_SEQ = 32, 256


def results_path(name: str) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, name)


def get_corpus(vocab: int = 512) -> SyntheticCorpus:
    # branch=8/topics=4 keeps the bigram table learnable inside the CPU
    # training budget while leaving a ~50× PPL gap to a collapsed model
    return SyntheticCorpus(CorpusConfig(vocab=vocab, n_topics=4, branch=8,
                                        seed=1234))


def get_trained_tiny(steps: int = TRAIN_STEPS,
                     force: bool = False) -> Tuple[Any, Tree,
                                                   SyntheticCorpus]:
    """Train (or restore) the tiny-lm benchmark subject."""
    cfg = registry.get("tiny-lm")
    corpus = get_corpus(cfg.vocab)
    ckpt_dir = results_path("tiny_trained")
    params0 = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    if not force and latest_step(ckpt_dir) == steps:
        params, _ = restore_checkpoint(ckpt_dir, params0)
        return cfg, params, corpus

    from repro.distributed.compression import CompressionConfig
    from repro.launch.train import make_train_step
    from repro.optim.adamw import AdamW, cosine_schedule
    opt = AdamW(lr=5e-3, weight_decay=0.01, clip_norm=1.0,
                schedule=cosine_schedule(warmup=50, total=steps))
    step_fn = jax.jit(make_train_step(cfg, PAR, opt, CompressionConfig()),
                      donate_argnums=(0,))
    state = {"params": params0, "opt": opt.init(params0),
             "residual": jnp.zeros((), jnp.float32)}
    it = corpus.batches(BATCH, SEQ, steps, split="train")
    t0 = time.time()
    for i, (tok, tgt) in enumerate(it):
        state, metrics = step_fn(state, {"tokens": jnp.asarray(tok),
                                         "targets": jnp.asarray(tgt)})
        if i % 100 == 0:
            print(f"[train tiny-lm] step {i} loss "
                  f"{float(metrics['loss']):.4f} ({time.time()-t0:.0f}s)")
    params = state["params"]
    save_checkpoint(ckpt_dir, steps, params)
    return cfg, params, corpus


def perplexity(cfg, params, corpus: SyntheticCorpus, *, n_batches: int = 8,
               batch: int = 8, seq: int = 256,
               split: str = "valid") -> float:
    loss_fn = jax.jit(lambda p, b: M.forward_loss(cfg, PAR, p, b))
    tot = 0.0
    for tok, tgt in corpus.batches(batch, seq, n_batches, split=split):
        tot += float(loss_fn(params, {"tokens": jnp.asarray(tok),
                                      "targets": jnp.asarray(tgt)}))
    ppl = math.exp(min(tot / n_batches, 30.0))
    return ppl


def calib_batches(corpus: SyntheticCorpus,
                  n_segments: int = CALIB_SEGMENTS,
                  seq: int = CALIB_SEQ) -> List[Dict[str, jax.Array]]:
    return [{"tokens": jnp.asarray(t)}
            for t, _ in corpus.batches(1, seq, n_segments, split="calib")]


def lm_task_suite(cfg, params, corpus, *, n_docs: int = 64,
                  seq: int = 128) -> Dict[str, float]:
    """Reasoning-proxy tasks for Table 2 (no GLUE offline): next-token
    top-1/top-5 accuracy and LAMBADA-style final-token accuracy."""
    logits_fn = jax.jit(lambda p, t: M.logits_fn(
        cfg, p, _hidden(cfg, p, t)))
    top1 = top5 = last = n_tok = n_last = 0
    for tok, tgt in corpus.batches(8, seq, n_docs // 8, split="valid"):
        lg = logits_fn(params, jnp.asarray(tok))
        lg = np.asarray(lg.astype(jnp.float32))
        order = np.argsort(-lg, axis=-1)[..., :5]
        hit1 = order[..., 0] == tgt
        hit5 = (order == tgt[..., None]).any(-1)
        top1 += hit1.sum(); top5 += hit5.sum(); n_tok += hit1.size
        last += hit1[:, -1].sum(); n_last += hit1.shape[0]
    return {"top1": top1 / n_tok, "top5": top5 / n_tok,
            "lambada_last": last / n_last}


def _hidden(cfg, params, tokens):
    """Backbone forward to final hidden states (no loss)."""
    from repro.models import transformer as T
    x, positions = M._backbone_inputs(cfg, params, {"tokens": tokens})
    for stage, sp in zip(cfg.stages, params["stages"]):
        x, _ = T.stage_full(cfg, PAR, stage, sp, x, positions, causal=True)
    return x


def quantize(method: str, cfg, params, corpus, *, preprocess: bool = False,
             qcfg_overrides: Optional[dict] = None) -> Tree:
    """One entry point for every quantizer the tables compare."""
    import dataclasses
    from repro.core.baselines.driver import quantize_model_baseline
    from repro.core.pipeline import quantize_model_ptq161
    from repro.core.preprocess import PreprocessConfig, restorative_lora
    from repro.core.qlinear import QuantConfig

    kw = {"ratio": 0.2, "multiple": 16, "steps": 16}
    kw.update(qcfg_overrides or {})
    qcfg = QuantConfig(**kw)
    base = params
    if preprocess:
        # pretraining-distribution LM batches (tokens, shifted targets)
        pp_batches = [{"tokens": jnp.asarray(t), "targets": jnp.asarray(g)}
                      for t, g in corpus.batches(4, 128, 8, split="calib")]
        base = restorative_lora(cfg, PAR, params, pp_batches, qcfg,
                                PreprocessConfig(rank=16, steps=150,
                                                 lr=3e-4),
                                min_dim=64)
    if method == "fp":
        return base
    if method == "ptq161":
        return quantize_model_ptq161(cfg, PAR, base,
                                     calib_batches(corpus), qcfg,
                                     min_dim=64)
    return quantize_model_baseline(cfg, PAR, base, calib_batches(corpus),
                                   method, min_dim=64)


def write_result(name: str, payload: Dict) -> str:
    path = results_path(name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    return path


def markdown_table(rows: List[Dict], cols: List[str]) -> str:
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(
            f"{r.get(c):.4g}" if isinstance(r.get(c), float)
            else str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)
