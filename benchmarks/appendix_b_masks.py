"""Appendix B analogue (paper Table 5): the activation-magnitude
structured mask vs an OWQ-style Hessian-ranked structured mask, inside
the same PTQ1.61 pipeline.  The paper's claim: under extremely low-bit
binarization the Hessian approximations blow up, while the direct
upper-bound ranking stays stable."""
from __future__ import annotations

from benchmarks.common import (get_trained_tiny, markdown_table,
                               perplexity, quantize, write_result)


def run(quick: bool = False) -> dict:
    cfg, params, corpus = get_trained_tiny()
    rows = []
    for name, overrides in [
            ("activation-mask (ours)", {}),
            ("hessian-mask (OWQ-style)", {"hessian_mask": True})]:
        qp = quantize("ptq161", cfg, params, corpus,
                      qcfg_overrides=overrides)
        rows.append({"mask": name,
                     "ppl_valid": perplexity(cfg, qp, corpus,
                                             split="valid")})
        print(f"[appB] {name:26s} ppl={rows[-1]['ppl_valid']:.2f}")
    payload = {"rows": rows}
    write_result("appendix_b_masks", payload)
    print(markdown_table(rows, ["mask", "ppl_valid"]))
    return payload


if __name__ == "__main__":
    run()
