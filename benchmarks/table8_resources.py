"""Table 8 analogue: resource requirements of each quantization stage
(wall time + peak RSS) on the tiny subject — the paper's point is that
PTQ1.61's extra preprocessing cost stays in the PTQ class, far below QAT."""
from __future__ import annotations

import resource
import time

from benchmarks.common import (get_trained_tiny, markdown_table, quantize,
                               write_result)


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024


def run(quick: bool = False) -> dict:
    cfg, params, corpus = get_trained_tiny()
    stages = [("datafree_init", "ptq161",
               dict(qcfg_overrides={"learn_scales": False, "steps": 0})),
              ("blockwise_opt", "ptq161", {}),
              ("preprocess+full", "ptq161", dict(preprocess=True))]
    if quick:
        stages = stages[:2]
    rows = []
    for name, method, kw in stages:
        t0 = time.time()
        quantize(method, cfg, params, corpus, **kw)
        rows.append({"stage": name, "wall_s": time.time() - t0,
                     "peak_rss_mb": _rss_mb()})
        print(f"[table8] {name:16s} {rows[-1]['wall_s']:.1f}s "
              f"rss={rows[-1]['peak_rss_mb']:.0f}MB")
    payload = {"rows": rows, "note": "paper: PTQ1.61 2h vs OneBit 24d "
               "on LLaMA-7B; same orders-of-magnitude gap applies"}
    write_result("table8_resources", payload)
    print(markdown_table(rows, ["stage", "wall_s", "peak_rss_mb"]))
    return payload


if __name__ == "__main__":
    run()
