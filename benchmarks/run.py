"""Benchmark entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Writes JSON to results/bench/ and prints each table as markdown.
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (appendix_b_masks, bits_accounting, fig5_preprocess,
                        fig6_ratio_sweep, kernel_bench, roofline,
                        serving_bench, table1_ppl, table2_tasks,
                        table3_ablation, table8_resources, table12_memory)

SUITES = [
    ("bits_accounting", bits_accounting.run),
    ("kernel_bench", kernel_bench.run),
    ("serving_bench", serving_bench.run),
    ("table12_memory", table12_memory.run),
    ("roofline", roofline.run),
    ("table1_ppl", table1_ppl.run),
    ("table3_ablation", table3_ablation.run),
    ("table2_tasks", table2_tasks.run),
    ("fig6_ratio_sweep", fig6_ratio_sweep.run),
    ("fig5_preprocess", fig5_preprocess.run),
    ("appendix_b_masks", appendix_b_masks.run),
    ("table8_resources", table8_resources.run),
]


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="reduced method/shape sets (CI budget)")
    p.add_argument("--only", default=None)
    args = p.parse_args(argv)

    failures = []
    for name, fn in SUITES:
        if args.only and args.only != name:
            continue
        print(f"\n{'='*70}\n== {name}\n{'='*70}", flush=True)
        t0 = time.time()
        try:
            fn(quick=args.quick)
            print(f"[{name}] done in {time.time()-t0:.0f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print("\nFAILED:", failures)
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
