"""Serving-runtime benchmark: contiguous vs paged KV cache under load.

Sweeps the request load (requests ≫ slots) over the tiny-lm subject and
reports, per backend, the engine's own metrics — tokens/s, time-to-first
-token, queue depth and page utilization — plus the KV memory each
backend actually reserves.  The point of the sweep: the contiguous
backend's cache is `n_slots × max_seq` no matter what arrives, while the
paged backend's footprint follows the resident tokens; a constrained
pool row exercises the preemption path so the recovery cost is visible
next to the full-parity numbers rather than hidden in a unit test.

Per-phase step timing: every row carries the engine's own
``phase_step_s`` breakdown (prefill vs decode wall time per jitted
step; each compiled shape's first call is split out into
"<phase>_compile", so the base series is pure steady-state), and a
``fused`` paged row runs the same load with N-fused QKV/gate-up
projections (``Engine(fuse_projections=True)``) so the decode fast
path's win is recorded in the BENCH json next to the baseline.
Phase timing stays enabled for EVERY row (its per-tick
block_until_ready sync is part of what is measured), so tokens_per_s
comparisons between rows are apples-to-apples; pass
``Engine(time_phases=False)`` to serve without the instrumentation.

Paged decode attention: the default ``paged`` rows run the Pallas
flash-decode kernel (scalar-prefetched block tables, per-token KV
traffic ∝ live context); a ``paged(xla)`` row pins the dense-gather
reference path (whole pool window per token) so the decode
attention-traffic win is recorded next to it.  Each row carries
``kv_read_kb_per_tok``: for kernel rows this is MEASURED — every
decode tick's (block_tables, context_lens) state is captured and the
kernel's own K/V index map is replayed over the grid
(``paged_attention.fetched_page_counts``, the same ``kv_block_index``
the BlockSpec runs) to count the page DMAs actually issued; for
XLA/contiguous rows it is the dense window the gather materializes.
The sweep ASSERTS, per slot per tick, that the kernel's fetches stay
≤ the slot's live tokens plus one page of slack — a live gate on the
index-map clamp, not a restatement of the cost model: breaking the
clamp (dead grid steps fetching fresh pages) fails the run.

Event-loop scenarios (both run under ``--quick`` so CI's artifact
carries their rows):

* **shared-prefix** — N requests with a page-aligned common prompt
  prefix, served with prefix sharing off vs on.  The sharing row
  records the prefix-cache counters (``pages_saved`` = pages attached
  instead of allocated+written) and the pool's peak page usage, and the
  sweep ASSERTS the greedy outputs are identical between the two runs
  (sharing is a memory optimization, not a numerics change) and that
  the shared run's peak is strictly lower.
* **mixed-priority** — realtime/standard/batch requests interleaved on
  a slot-starved engine; one row per class with TTFT/TBT p50/p95 from
  the engine's per-class metrics, making the weighted-deficit
  scheduler's service shares (and the aging bound: batch still
  completes) visible in the BENCH json.

Emits a BENCH json (results/bench/serving_bench.json).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import markdown_table, write_result
from repro.configs import registry
from repro.kernels import autotune
from repro.models import model as M
from repro.models.common import Parallel
from repro.runtime.engine import Engine
from repro.runtime.paged_cache import pages_for_tokens

PAR = Parallel(remat=False, attn_chunk=32)
N_SLOTS, MAX_SEQ, PAGE = 4, 128, 16
MAX_NEW = 16


def kv_bytes(cfg, *, paged: bool, pool_pages: int = 0) -> int:
    """Reserved KV bytes (k+v, bf16) for the tiny-lm dense stack."""
    hkv = cfg.n_kv_heads
    per_tok = 2 * hkv * cfg.head_dim_ * 2 * cfg.n_layers
    toks = pool_pages * PAGE if paged else N_SLOTS * MAX_SEQ
    return toks * per_tok


def measured_kernel_read_kb_per_tok(cfg, tick_states) -> float:
    """MEASURED KV bytes per generated token through the flash-decode
    kernel: replay the kernel's own K/V index map over every recorded
    decode-tick state and count the page DMAs it issues
    (``fetched_page_counts`` shares ``kv_block_index`` with the
    BlockSpec, so this tracks the kernel's real addressing, not a
    parallel model) — and ASSERT the live-token bound per slot per
    tick: fetched pages × page_size ≤ live tokens + one page of slack
    (inactive rows cost exactly the one clamped slack page)."""
    from repro.kernels.paged_attention import fetched_page_counts
    per_tok = autotune.paged_kv_bytes_per_token(cfg.n_kv_heads,
                                                cfg.head_dim_)
    total_bytes, total_toks = 0, 0
    for bt, lens in tick_states:
        counts = fetched_page_counts(bt, lens, PAGE)
        for slot, (fetched, live) in enumerate(zip(counts, lens)):
            assert fetched * PAGE <= live + PAGE, (
                f"kernel index map fetched {fetched} pages for a slot "
                f"with {live} live tokens (tables row "
                f"{bt[slot].tolist()}) — reads must scale with live "
                f"context, not table capacity")
        total_bytes += int(counts.sum()) * PAGE * per_tok
        total_toks += int((lens > 0).sum())    # one token per live slot
    return total_bytes * cfg.n_layers / max(total_toks, 1) / 1024


def dense_read_kb_per_tok(cfg, *, backend: str) -> float:
    """The dense paths' per-step window (cost model): contiguous
    attends the whole (B, max_seq) ring; the XLA paged gather
    materializes nblk*ps slots regardless of liveness."""
    per_tok = autotune.paged_kv_bytes_per_token(cfg.n_kv_heads,
                                                cfg.head_dim_)
    slots = (MAX_SEQ if backend == "contiguous"
             else pages_for_tokens(MAX_SEQ, PAGE) * PAGE)
    return slots * per_tok * cfg.n_layers / 1024


def bench_one(cfg, params, n_requests: int, *, paged: bool,
              pool_pages=None, seed: int = 0, fused: bool = False,
              paged_kernel: bool = True) -> dict:
    eng = Engine(cfg, PAR, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                 prefill_buckets=(16, 64), paged=paged, page_size=PAGE,
                 pool_pages=pool_pages, seed=seed, fuse_projections=fused,
                 paged_kernel=paged_kernel)
    # only claim (and gate on) measured kernel traffic when the engine
    # really dispatches the kernel for this shape — on a TPU backend an
    # infeasible layout (e.g. dh % 128) silently keeps the dense path
    from repro.kernels import ops
    kernel_active = bool(
        paged and paged_kernel
        and ops.paged_attention_blocks(
            PAGE, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads,
            cfg.head_dim_) is not None)
    tick_states = []
    if kernel_active:
        # capture each decode tick's scalar-prefetch operands so the
        # kernel's fetch addressing can be replayed and asserted on
        orig_decode = eng.backend.decode
        def spy_decode(params_, toks, pos):
            tick_states.append((eng.backend.tables.as_array().copy(),
                                eng.backend.tables.context_lens().copy()))
            return orig_decode(params_, toks, pos)
        eng.backend.decode = spy_decode
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        plen = int(rng.integers(4, MAX_SEQ // 4))
        prompt = rng.integers(1, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(eng.submit(prompt, max_new=MAX_NEW))
    t0 = time.time()
    eng.run()
    wall = time.time() - t0
    snap = eng.metrics.snapshot()
    phases = snap["phase_step_s"]
    pool = (pool_pages if pool_pages is not None
            else N_SLOTS * pages_for_tokens(MAX_SEQ, PAGE)) if paged else 0
    if kernel_active:
        read_kb = measured_kernel_read_kb_per_tok(cfg, tick_states)
    else:
        read_kb = dense_read_kb_per_tok(
            cfg, backend="contiguous" if not paged else "xla")
    return {
        "backend": eng.backend.name + ("(tight)" if pool_pages else "")
        + ("(fused)" if fused else "")
        + ("(xla)" if paged and not paged_kernel else ""),
        "requests": n_requests,
        "all_done": all(r.done for r in reqs),
        "tokens_per_s": snap["generated_tokens"] / max(wall, 1e-9),
        "ttft_mean_s": snap["ttft_mean_s"],
        "queue_depth_max": snap["queue_depth_max"],
        "page_util_mean": snap["page_util_mean"],
        "page_util_max": snap["page_util_max"],
        "preemptions": snap["preemptions"],
        "kv_mb_reserved": kv_bytes(cfg, paged=paged, pool_pages=pool) / 1e6,
        "kv_read_kb_per_tok": read_kb,
        "prefill_step_ms": phases.get("prefill", {}).get(
            "mean_s", 0.0) * 1e3,
        "decode_step_ms": phases.get("decode", {}).get(
            "mean_s", 0.0) * 1e3,
        "phase_step_s": phases,
    }


def bench_shared_prefix(cfg, params, n_requests: int) -> list:
    """N same-prefix requests, sharing off vs on: pool accounting plus a
    live greedy-identity assertion (the engine-level restatement of the
    test-suite claim, running inside the sweep)."""
    rng = np.random.default_rng(7)
    common = rng.integers(1, cfg.vocab, size=3 * PAGE).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(
        1, cfg.vocab, size=6).astype(np.int32)]) for _ in range(n_requests)]
    rows, outs = [], {}
    for sharing in (False, True):
        # every request in a slot at once: the common pages' refcount
        # peaks at n_requests and the pool accounting below is exact
        # (pages freed with a finished cohort are not retained — a
        # straggler admitted later re-prefills; see ROADMAP follow-up)
        eng = Engine(cfg, PAR, params, n_slots=n_requests, max_seq=MAX_SEQ,
                     prefill_buckets=(64,), paged=True, page_size=PAGE,
                     prefix_sharing=sharing)
        reqs = [eng.submit(p, max_new=MAX_NEW) for p in prompts]
        t0 = time.time()
        eng.run()
        wall = time.time() - t0
        assert all(r.done for r in reqs)
        outs[sharing] = [r.out_tokens for r in reqs]
        snap = eng.metrics.snapshot()
        pstats = eng.prefix_stats() or {}
        rows.append({
            "backend": "paged(shared)" if sharing else "paged(unshared)",
            "requests": n_requests,
            "tokens_per_s": snap["generated_tokens"] / max(wall, 1e-9),
            "ttft_mean_s": snap["ttft_mean_s"],
            "tbt_p50_ms": snap["tbt_p50_s"] * 1e3,
            "tbt_p95_ms": snap["tbt_p95_s"] * 1e3,
            "peak_pages": eng.backend.pool.stats().peak_in_use,
            "pages_saved": pstats.get("pages_attached", 0),
            "prefix_hits": pstats.get("hits", 0),
            "cow_copies": pstats.get("cow_copies", 0),
        })
    assert outs[False] == outs[True], (
        "prefix sharing changed greedy outputs — COW attach must be a "
        "pure memory optimization")
    shared, unshared = rows[1], rows[0]
    assert shared["pages_saved"] >= (n_requests - 1) * (
        len(common) // PAGE), "common pages must be attached, not realloc'd"
    assert shared["peak_pages"] < unshared["peak_pages"], (
        "sharing must lower the pool's peak page usage")
    return rows


def bench_chunked_prefill(cfg, params) -> list:
    """Mixed load: short realtime requests decoding while long batch
    prompts keep arriving — whole-prompt prefill vs chunked prefill.

    The whole-prompt engine runs each long prompt as ONE bucketed dense
    pass inside a tick, so every in-flight decode sees that tick's full
    prefill latency as an inter-token gap; the chunked engine advances
    prefills ``prefill_chunk`` tokens per tick, interleaved with the
    decode step.  A steady stream of long prompts keeps a prefill in
    flight for most of the run, so both engines' p95 actually samples
    their prefill-tick gaps.  The sweep ASSERTS the short requests'
    decode TBT p95 improves under chunking, and that the chunked run
    never invoked the whole-prompt prefill at all (no dense
    (B, bucket, hkv, dh) KV intermediate was ever built — only
    "prefill_chunk" phase entries exist).  Each row carries the prefill
    KV-traffic accounting (``prefill_kv_read_kb_per_tok``, mirroring
    ``paged_read_bytes``): chunked moves context+chunk pages per chunk;
    whole-prompt materializes the bucket-sized dense cache per prefill.

    Like the ``paged(xla)`` rows above, BOTH timing rows pin
    ``paged_kernel=False``: on this CPU runner the Pallas kernels
    execute in interpret mode, whose per-grid-step Python overhead
    would swamp the scheduling effect being measured — the XLA
    dense-gather paths are bit-compatible stand-ins (the kernel's own
    numerics/addressing are gated by tests and the chunked-prefix
    scenario below).
    """
    max_seq, chunk = 256, 32
    rng = np.random.default_rng(13)
    shorts = [rng.integers(1, cfg.vocab, size=8).astype(np.int32)
              for _ in range(3)]
    longs = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
             for n in (180, 200, 190, 170, 210, 185, 175, 195)]
    per_tok = autotune.paged_kv_bytes_per_token(cfg.n_kv_heads,
                                                cfg.head_dim_)
    rows = []
    for mode in ("whole", "chunked"):
        eng = Engine(cfg, PAR, params, n_slots=4, max_seq=max_seq,
                     prefill_buckets=(16, max_seq), paged=True,
                     page_size=PAGE, paged_kernel=False,
                     chunked_prefill=(mode == "chunked"),
                     prefill_chunk=chunk)
        sreqs = [eng.submit(p, max_new=40, priority="realtime")
                 for p in shorts]
        t0 = time.time()
        for _ in range(3):          # get the shorts decoding first
            eng.tick()
        pending = list(longs)
        lreqs = []
        ticks = 0
        while eng.has_work or pending:
            if pending and ticks % 4 == 0:
                lreqs.append(eng.submit(pending.pop(0), max_new=4,
                                        priority="batch"))
            eng.tick()
            ticks += 1
            assert ticks < 5000, "mixed-load scenario failed to drain"
        wall = time.time() - t0
        assert all(r.done for r in sreqs + lreqs)
        snap = eng.metrics.snapshot()
        rt = snap["per_class"].get("realtime", {})
        bt = snap["per_class"].get("batch", {})
        if mode == "chunked":
            m = eng.metrics
            assert m.prefill_chunks > 0 and \
                "prefill" not in snap["phase_step_s"], (
                    "chunked engine must never take the whole-prompt "
                    "prefill path (no dense bucket KV intermediate)")
            kv_kb = (eng.backend.prefill_kv_read_bytes
                     / max(m.prefill_chunk_tokens, 1) / 1024)
        else:
            # every whole-prompt prefill materializes its bucket-sized
            # dense KV cache and re-reads it in the splice scatter
            n_pref = snap["prefills"]
            toks = sum(len(p) for p in shorts) + sum(len(p) for p in longs)
            kv_kb = (n_pref and
                     max_seq * per_tok * cfg.n_layers / 1024 * n_pref
                     / max(toks, 1))
        rows.append({
            "backend": f"paged({mode}-prefill)",
            "requests": len(shorts) + len(longs),
            "tokens_per_s": snap["generated_tokens"] / max(wall, 1e-9),
            "decode_tbt_p50_ms": rt.get("tbt_p50_s", 0.0) * 1e3,
            "decode_tbt_p95_ms": rt.get("tbt_p95_s", 0.0) * 1e3,
            "long_ttft_mean_s": bt.get("ttft_mean_s", 0.0),
            "prefill_chunks": eng.metrics.prefill_chunks,
            "prefill_kv_read_kb_per_tok": kv_kb,
        })
    whole, chunked = rows
    assert chunked["decode_tbt_p95_ms"] < whole["decode_tbt_p95_ms"], (
        f"chunked prefill must bound the decode inter-token gap under "
        f"concurrent long prefills: p95 {chunked['decode_tbt_p95_ms']:.2f}"
        f"ms vs whole-prompt {whole['decode_tbt_p95_ms']:.2f}ms")
    return rows


def bench_chunked_prefix(cfg, params) -> list:
    """Chunked prefill × prefix cache × retention: a cohort shares a
    page-aligned common prefix; a straggler arrives AFTER the cohort
    finished.  ASSERTS fully-shared chunks execute zero prefill-kernel
    calls (the straggler pays exactly the tail chunk) and that the
    retention LRU kept the hit window open past the cohort's death."""
    chunk = 2 * PAGE
    rng = np.random.default_rng(17)
    common = rng.integers(1, cfg.vocab, size=4 * PAGE).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(
        1, cfg.vocab, size=6).astype(np.int32)]) for _ in range(4)]
    eng = Engine(cfg, PAR, params, n_slots=4, max_seq=MAX_SEQ, paged=True,
                 page_size=PAGE, chunked_prefill=True, prefill_chunk=chunk,
                 prefix_sharing=True, prefix_retain_pages=8)
    reqs = [eng.submit(p, max_new=8) for p in prompts]
    eng.run()
    assert all(r.done for r in reqs)
    cohort_calls = eng.backend.prefill_chunk_calls
    cohort_skipped = eng.metrics.prefill_tokens_skipped
    assert cohort_skipped > 0, \
        "cohort peers must skip chunks their peers already computed"
    # straggler after the cohort died: retention keeps the prefix pages
    straggler = eng.submit(np.concatenate(
        [common, rng.integers(1, cfg.vocab, size=3).astype(np.int32)]),
        max_new=4)
    eng.run()
    assert straggler.done
    tail_calls = eng.backend.prefill_chunk_calls - cohort_calls
    # 4 common pages retained -> frontier starts at 64 of 67 tokens:
    # exactly ONE chunk call for the tail, zero for the shared chunks
    assert tail_calls == 1, (
        f"straggler must pay only its tail chunk (got {tail_calls} "
        f"calls) — fully prefix-shared chunks run zero kernel calls")
    st = eng.prefix_stats()
    assert st["retained"] > 0 and st["hits"] >= 1
    return [{
        "backend": "paged(chunked+prefix+retain)",
        "requests": len(reqs) + 1,
        "prefill_chunks": eng.metrics.prefill_chunks,
        "prefill_tokens": eng.metrics.prefill_chunk_tokens,
        "tokens_skipped": eng.metrics.prefill_tokens_skipped,
        "straggler_chunks": tail_calls,
        "pages_retained": st["retained"],
        "prefix_hits": st["hits"],
        "cow_copies": st["cow_copies"],
    }]


def bench_mixed_priority(cfg, params, n_requests: int = 12) -> list:
    """Interleaved realtime/standard/batch on a slot-starved engine:
    per-class TTFT/TBT from the engine's own metrics."""
    classes = ("realtime", "standard", "batch")
    rng = np.random.default_rng(11)
    eng = Engine(cfg, PAR, params, n_slots=2, max_seq=MAX_SEQ,
                 prefill_buckets=(16, 64), paged=True, page_size=PAGE)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(4, MAX_SEQ // 4))
        prompt = rng.integers(1, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(eng.submit(prompt, max_new=MAX_NEW,
                               priority=classes[i % len(classes)]))
    eng.run()
    assert all(r.done for r in reqs), \
        "aging term must bound every class's wait (no starvation)"
    per_class = eng.metrics.snapshot()["per_class"]
    rows = []
    for cls in classes:
        pc = per_class.get(cls, {})
        rows.append({
            "backend": f"paged(prio:{cls})",
            "requests": pc.get("requests", 0),
            "completed": pc.get("completed", 0),
            "ttft_mean_s": pc.get("ttft_mean_s", 0.0),
            "ttft_p95_s": pc.get("ttft_p95_s", 0.0),
            "tbt_p50_ms": pc.get("tbt_p50_s", 0.0) * 1e3,
            "tbt_p95_ms": pc.get("tbt_p95_s", 0.0) * 1e3,
        })
    return rows


def check_tbt_regression(payload: dict, prev_path: str,
                         threshold: float = 1.2) -> None:
    """CI gate: fail when the chunked-prefill mixed-load decode TBT p95
    regresses more than ``threshold`` against the committed BENCH json.

    The gated quantity is the p95 NORMALIZED by the same run's
    whole-prompt p95 ("what fraction of the whole-prompt stall does a
    concurrent decode still see") — absolute milliseconds differ wildly
    between the dev box and CI's shared 2-core runner, but the ratio is
    scale-free: if chunked prefill stops bounding the inter-token gap
    (a scheduling or budget regression), the ratio blows up on any
    machine."""
    import json
    import os
    if not os.path.exists(prev_path):
        print(f"[regression] no committed baseline at {prev_path}; "
              f"skipping gate")
        return
    with open(prev_path) as f:
        prev = json.load(f)

    def ratio(rows):
        r = {row["backend"]: row for row in rows}
        whole = r.get("paged(whole-prefill)", {}).get("decode_tbt_p95_ms")
        chunk = r.get("paged(chunked-prefill)", {}).get("decode_tbt_p95_ms")
        if not whole or chunk is None:
            return None
        return chunk / whole

    old = ratio(prev.get("chunked_prefill_rows", []))
    new = ratio(payload["chunked_prefill_rows"])
    if old is None:
        print("[regression] baseline lacks the chunked scenario; "
              "skipping gate")
        return
    print(f"[regression] mixed-load decode TBT p95 / whole-prompt p95: "
          f"{new:.3f} (committed {old:.3f})")
    # the ratio's run-to-run p95 jitter is ~±0.15 even on a quiet box;
    # the additive slack keeps ordinary jitter out of the gate while a
    # real regression (chunking no longer bounding the gap, ratio → 1)
    # still fails on any machine
    if new > max(old * threshold, old + 0.25):
        raise SystemExit(
            f"chunked-prefill decode TBT p95 regressed "
            f">{(threshold - 1) * 100:.0f}% relative to the whole-prompt "
            f"baseline: ratio {new:.3f} vs committed {old:.3f}")


def run(quick: bool = False, check_regression: bool = False) -> dict:
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    loads = (N_SLOTS, 3 * N_SLOTS) if quick else \
        (N_SLOTS, 2 * N_SLOTS, 4 * N_SLOTS)
    # tight pool: enough for ~2.5 full-length requests across 4 slots —
    # forces exhaustion → preemption under the higher loads
    tight = int(2.5 * pages_for_tokens(MAX_SEQ // 4 + MAX_NEW, PAGE))
    rows = []
    for n in loads:
        rows.append(bench_one(cfg, params, n, paged=False))
        rows.append(bench_one(cfg, params, n, paged=True))
        rows.append(bench_one(cfg, params, n, paged=True,
                              paged_kernel=False))
        rows.append(bench_one(cfg, params, n, paged=True, fused=True))
        rows.append(bench_one(cfg, params, n, paged=True,
                              pool_pages=tight))
    shared_rows = bench_shared_prefix(cfg, params,
                                      2 * N_SLOTS if quick else 3 * N_SLOTS)
    prio_rows = bench_mixed_priority(cfg, params,
                                     9 if quick else 15)
    # chunked-prefill scenarios run in --quick too: CI's artifact gates
    # on the mixed-load decode TBT p95 row
    chunked_rows = (bench_chunked_prefill(cfg, params)
                    + bench_chunked_prefix(cfg, params))
    payload = {"n_slots": N_SLOTS, "max_seq": MAX_SEQ, "page_size": PAGE,
               "tight_pool_pages": tight, "rows": rows,
               "shared_prefix_rows": shared_rows,
               "priority_rows": prio_rows,
               "chunked_prefill_rows": chunked_rows}
    if check_regression:
        check_tbt_regression(payload,
                             "results/bench/serving_bench.json")
    write_result("serving_bench", payload)
    print(markdown_table(rows, ["backend", "requests", "tokens_per_s",
                                "ttft_mean_s", "queue_depth_max",
                                "page_util_max", "preemptions",
                                "kv_mb_reserved", "kv_read_kb_per_tok",
                                "prefill_step_ms", "decode_step_ms"]))
    print()
    print(markdown_table(shared_rows + prio_rows,
                         ["backend", "requests", "completed",
                          "tokens_per_s", "ttft_mean_s", "ttft_p95_s",
                          "tbt_p50_ms", "tbt_p95_ms", "peak_pages",
                          "pages_saved", "prefix_hits", "cow_copies"]))
    print()
    print(markdown_table(chunked_rows,
                         ["backend", "requests", "tokens_per_s",
                          "decode_tbt_p50_ms", "decode_tbt_p95_ms",
                          "long_ttft_mean_s", "prefill_chunks",
                          "prefill_kv_read_kb_per_tok", "tokens_skipped",
                          "straggler_chunks", "pages_retained"]))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced load sweep (CI budget)")
    ap.add_argument("--check-regression", action="store_true",
                    help="fail when the chunked mixed-load decode TBT "
                         "p95 regresses >20%% vs the committed "
                         "results/bench/serving_bench.json")
    args = ap.parse_args()
    run(quick=args.quick, check_regression=args.check_regression)
