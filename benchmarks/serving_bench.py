"""Serving-runtime benchmark: contiguous vs paged KV cache under load.

Sweeps the request load (requests ≫ slots) over the tiny-lm subject and
reports, per backend, the engine's own metrics — tokens/s, time-to-first
-token, queue depth and page utilization — plus the KV memory each
backend actually reserves.  The point of the sweep: the contiguous
backend's cache is `n_slots × max_seq` no matter what arrives, while the
paged backend's footprint follows the resident tokens; a constrained
pool row exercises the preemption path so the recovery cost is visible
next to the full-parity numbers rather than hidden in a unit test.

Per-phase step timing: every row carries the engine's own
``phase_step_s`` breakdown (prefill vs decode wall time per jitted
step; each compiled shape's first call is split out into
"<phase>_compile", so the base series is pure steady-state), and a
``fused`` paged row runs the same load with N-fused QKV/gate-up
projections (``Engine(fuse_projections=True)``) so the decode fast
path's win is recorded in the BENCH json next to the baseline.
Phase timing stays enabled for EVERY row (its per-tick
block_until_ready sync is part of what is measured), so tokens_per_s
comparisons between rows are apples-to-apples; pass
``Engine(time_phases=False)`` to serve without the instrumentation.

Emits a BENCH json (results/bench/serving_bench.json).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import markdown_table, write_result
from repro.configs import registry
from repro.models import model as M
from repro.models.common import Parallel
from repro.runtime.engine import Engine
from repro.runtime.paged_cache import pages_for_tokens

PAR = Parallel(remat=False, attn_chunk=32)
N_SLOTS, MAX_SEQ, PAGE = 4, 128, 16
MAX_NEW = 16


def kv_bytes(cfg, *, paged: bool, pool_pages: int = 0) -> int:
    """Reserved KV bytes (k+v, bf16) for the tiny-lm dense stack."""
    hkv = cfg.n_kv_heads
    per_tok = 2 * hkv * cfg.head_dim_ * 2 * cfg.n_layers
    toks = pool_pages * PAGE if paged else N_SLOTS * MAX_SEQ
    return toks * per_tok


def bench_one(cfg, params, n_requests: int, *, paged: bool,
              pool_pages=None, seed: int = 0, fused: bool = False) -> dict:
    eng = Engine(cfg, PAR, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                 prefill_buckets=(16, 64), paged=paged, page_size=PAGE,
                 pool_pages=pool_pages, seed=seed, fuse_projections=fused)
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        plen = int(rng.integers(4, MAX_SEQ // 4))
        prompt = rng.integers(1, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(eng.submit(prompt, max_new=MAX_NEW))
    t0 = time.time()
    eng.run()
    wall = time.time() - t0
    snap = eng.metrics.snapshot()
    phases = snap["phase_step_s"]
    pool = (pool_pages if pool_pages is not None
            else N_SLOTS * pages_for_tokens(MAX_SEQ, PAGE)) if paged else 0
    return {
        "backend": eng.backend.name + ("(tight)" if pool_pages else "")
        + ("(fused)" if fused else ""),
        "requests": n_requests,
        "all_done": all(r.done for r in reqs),
        "tokens_per_s": snap["generated_tokens"] / max(wall, 1e-9),
        "ttft_mean_s": snap["ttft_mean_s"],
        "queue_depth_max": snap["queue_depth_max"],
        "page_util_mean": snap["page_util_mean"],
        "page_util_max": snap["page_util_max"],
        "preemptions": snap["preemptions"],
        "kv_mb_reserved": kv_bytes(cfg, paged=paged, pool_pages=pool) / 1e6,
        "prefill_step_ms": phases.get("prefill", {}).get(
            "mean_s", 0.0) * 1e3,
        "decode_step_ms": phases.get("decode", {}).get(
            "mean_s", 0.0) * 1e3,
        "phase_step_s": phases,
    }


def run(quick: bool = False) -> dict:
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    loads = (N_SLOTS, 3 * N_SLOTS) if quick else \
        (N_SLOTS, 2 * N_SLOTS, 4 * N_SLOTS)
    # tight pool: enough for ~2.5 full-length requests across 4 slots —
    # forces exhaustion → preemption under the higher loads
    tight = int(2.5 * pages_for_tokens(MAX_SEQ // 4 + MAX_NEW, PAGE))
    rows = []
    for n in loads:
        rows.append(bench_one(cfg, params, n, paged=False))
        rows.append(bench_one(cfg, params, n, paged=True))
        rows.append(bench_one(cfg, params, n, paged=True, fused=True))
        rows.append(bench_one(cfg, params, n, paged=True,
                              pool_pages=tight))
    payload = {"n_slots": N_SLOTS, "max_seq": MAX_SEQ, "page_size": PAGE,
               "tight_pool_pages": tight, "rows": rows}
    write_result("serving_bench", payload)
    print(markdown_table(rows, ["backend", "requests", "tokens_per_s",
                                "ttft_mean_s", "queue_depth_max",
                                "page_util_max", "preemptions",
                                "kv_mb_reserved", "prefill_step_ms",
                                "decode_step_ms"]))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced load sweep (CI budget)")
    run(quick=ap.parse_args().quick)
