"""Table 1/6 analogue: PPL of FP vs 2-bit baselines vs sub-2-bit methods
on the trained tiny-lm subject (WikiText2/C4 → synthetic valid/calib
splits).  Also reports each method's effective bits/weight (App. A)."""
from __future__ import annotations

import time
from benchmarks.common import (get_trained_tiny, markdown_table,
                               perplexity, quantize, write_result)
from repro.core.baselines.driver import method_bits
from repro.core.bits import model_bits

METHODS = ["fp", "rtn-2", "gptq-2", "awq-2", "pbllm", "billm",
           "ptq161*", "ptq161"]       # * = no preprocessing (paper's *)


def bits_of(method: str, qparams) -> float:
    if method == "fp":
        return 16.0
    if method.startswith("ptq161"):
        return model_bits(qparams)["avg_bits_per_quantized_weight"]
    return method_bits(method.split("*")[0])


def run(quick: bool = False) -> dict:
    cfg, params, corpus = get_trained_tiny()
    methods = (["fp", "rtn-2", "pbllm", "ptq161*", "ptq161"] if quick
               else METHODS)
    rows = []
    for m in methods:
        t0 = time.time()
        base = m.rstrip("*")
        pre = (m == "ptq161")          # full PTQ1.61 includes preprocess
        qp = quantize("ptq161" if base == "ptq161" else base,
                      cfg, params, corpus, preprocess=pre)
        row = {
            "method": m,
            "bits": bits_of(m, qp),
            "ppl_valid": perplexity(cfg, qp, corpus, split="valid"),
            "ppl_calib": perplexity(cfg, qp, corpus, split="calib"),
            "quant_s": time.time() - t0,
        }
        rows.append(row)
        print(f"[table1] {m:10s} bits={row['bits']:.2f} "
              f"ppl={row['ppl_valid']:.2f} ({row['quant_s']:.0f}s)")
    payload = {"rows": rows,
               "bigram_ceiling": corpus.bigram_ceiling_ppl()}
    write_result("table1_ppl", payload)
    print(markdown_table(rows, ["method", "bits", "ppl_valid",
                                "ppl_calib"]))
    return payload


if __name__ == "__main__":
    run()
