"""Figure 6 analogue: salient-channel ratio sweep — PPL improves with
ratio but bits/weight crosses 2.0 near 30% (why the paper picks 20%)."""
from __future__ import annotations

from benchmarks.common import (get_trained_tiny, markdown_table,
                               perplexity, quantize, write_result)
from repro.core.bits import model_bits, paper_closed_form

RATIOS = [0.1, 0.2, 0.3, 0.4]


def run(quick: bool = False) -> dict:
    cfg, params, corpus = get_trained_tiny()
    ratios = [0.1, 0.3] if quick else RATIOS
    rows = []
    for r in ratios:
        qp = quantize("ptq161", cfg, params, corpus,
                      qcfg_overrides={"ratio": r})
        rows.append({
            "ratio": r,
            "ppl_valid": perplexity(cfg, qp, corpus, split="valid"),
            "bits_tiny": model_bits(qp)["avg_bits_per_quantized_weight"],
            # the paper-scale (4096²) bit cost at this ratio
            "bits_4096": paper_closed_form(4096, 4096, r).total_bits,
        })
        print(f"[fig6] ratio={r} ppl={rows[-1]['ppl_valid']:.2f} "
              f"bits@4096={rows[-1]['bits_4096']:.2f}")
    payload = {"rows": rows}
    write_result("fig6_ratio_sweep", payload)
    print(markdown_table(rows, ["ratio", "ppl_valid", "bits_tiny",
                                "bits_4096"]))
    return payload


if __name__ == "__main__":
    run()
