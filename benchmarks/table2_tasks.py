"""Table 2 analogue: task-suite accuracy of quantized models.

Offline proxy for PIQA/ARC/HellaSwag/LAMBADA (DESIGN.md §8): next-token
top-1/top-5 accuracy and LAMBADA-style final-token accuracy on held-out
synthetic documents.  The paper's claim shape — PTQ1.61 ≥ sub-2-bit
baselines, close to FP — is what we validate.
"""
from __future__ import annotations

from benchmarks.common import (get_trained_tiny, lm_task_suite,
                               markdown_table, quantize, write_result)

METHODS = ["fp", "rtn-2", "pbllm", "billm", "ptq161*", "ptq161"]


def run(quick: bool = False) -> dict:
    cfg, params, corpus = get_trained_tiny()
    methods = ["fp", "pbllm", "ptq161"] if quick else METHODS
    rows = []
    for m in methods:
        base = m.rstrip("*")
        qp = quantize("ptq161" if base == "ptq161" else base, cfg, params,
                      corpus, preprocess=(m == "ptq161"))
        row = {"method": m, **lm_task_suite(cfg, qp, corpus)}
        rows.append(row)
        print(f"[table2] {m:10s} top1={row['top1']:.3f} "
              f"top5={row['top5']:.3f} last={row['lambada_last']:.3f}")
    payload = {"rows": rows}
    write_result("table2_tasks", payload)
    print(markdown_table(rows, ["method", "top1", "top5", "lambada_last"]))
    return payload


if __name__ == "__main__":
    run()
