"""ShapeDtypeStruct input stand-ins + PartitionSpecs per dry-run cell.

`input_specs(cfg, cell, par, rules)` returns (abstract inputs,
PartitionSpec tree) for the step kind of the cell:
  train   : {tokens (B,S), targets (B,S) [, vision_embeds / frames]}
  prefill : {tokens (B,S) [, extras]}
  decode  : (token (B,), pos (B,), caches)  — caches sized by the cell
            (ring windows bound SWA/local archs; recurrent state is O(1)).

Frontend stubs: llava's vision tower contributes 576 precomputed patch
embeddings inside the sequence budget; seamless's speech encoder sees
ENC_FRAMES precomputed frame embeddings (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed.sharding import Rules
from repro.models import model as M
from repro.models.common import Parallel
from repro.models.param import abstractify, axes_tree

Tree = Any
ENC_FRAMES = 1024       # seamless stub: fixed speech-frame budget
SDS = jax.ShapeDtypeStruct


def _bspec(rules: Rules, par: Parallel, *rest) -> PS:
    if not par.shard_batch:
        return PS(None, *rest)
    dp = rules.dp_axes if len(rules.dp_axes) > 1 else rules.dp_axes[0]
    return PS(dp, *rest)


def train_inputs(cfg: ArchConfig, cell: ShapeCell, par: Parallel,
                 rules: Rules) -> Tuple[Dict, Dict]:
    b, s = cell.global_batch, cell.seq_len
    inp = {"tokens": SDS((b, s), jnp.int32),
           "targets": SDS((b, s), jnp.int32)}
    spec = {"tokens": _bspec(rules, par, None),
            "targets": _bspec(rules, par, None)}
    if cfg.frontend == "vision":
        inp["vision_embeds"] = SDS((b, cfg.frontend_tokens, cfg.d_model),
                                   jnp.bfloat16)
        spec["vision_embeds"] = _bspec(rules, par, None, None)
    if cfg.enc_dec:
        inp["frames"] = SDS((b, ENC_FRAMES, cfg.d_model), jnp.bfloat16)
        spec["frames"] = _bspec(rules, par, None, None)
    return inp, spec


def prefill_inputs(cfg: ArchConfig, cell: ShapeCell, par: Parallel,
                   rules: Rules) -> Tuple[Dict, Dict]:
    inp, spec = train_inputs(cfg, cell, par, rules)
    del inp["targets"], spec["targets"]
    return inp, spec


def decode_inputs(cfg: ArchConfig, cell: ShapeCell, par: Parallel,
                  rules: Rules) -> Tuple[Tuple, Tuple]:
    b = cell.global_batch
    cache_decl = M.init_caches(cfg, par, b, cell.seq_len,
                               enc_len=ENC_FRAMES if cfg.enc_dec else 0)
    caches = abstractify(cache_decl)
    cache_spec = jax.tree.map(lambda p: rules.spec(p.axes), cache_decl,
                              is_leaf=lambda x: hasattr(x, "axes"))
    if not par.shard_batch:
        # strip the data axis from cache batch dims
        def debatch(p, s):
            parts = list(s) + [None] * (len(p.shape) - len(s))
            fixed = [None if i == 1 else a for i, a in enumerate(parts)]
            return PS(*fixed)
        cache_spec = jax.tree.map(
            debatch, cache_decl, cache_spec,
            is_leaf=lambda x: hasattr(x, "axes"))
    tok = SDS((b,), jnp.int32)
    pos = SDS((b,), jnp.int32)
    tspec = _bspec(rules, par)
    return (tok, pos, caches), (tspec, tspec, cache_spec)
