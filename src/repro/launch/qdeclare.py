"""Abstract quantized-parameter declaration for serving dry-runs.

Walks the P-declared parameter tree; every quantizable leaf becomes a
QLinear of ``jax.ShapeDtypeStruct`` (packed shapes per QuantConfig), with
the matching PartitionSpec QLinear emitted in the same pass — no real
weights, no device allocation, exactly what ``.lower()`` needs.
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qlinear import QLinear, QuantConfig
from repro.core.saliency import round_salient
from repro.core.select import is_quantizable
from repro.distributed.sharding import Rules, qlinear_specs
from repro.models import model as M
from repro.models.common import Parallel
from repro.models.param import P, is_leaf as is_p

Tree = Any


def declare_qlinear(p: P, qcfg: QuantConfig) -> QLinear:
    """P((…,K,N)) -> abstract QLinear (ShapeDtypeStruct fields)."""
    lead = p.shape[:-2]
    k, n = p.shape[-2:]
    k_s = round_salient(k, qcfg.ratio, qcfg.multiple)
    k_b = k - k_s
    sds = jax.ShapeDtypeStruct
    return QLinear(
        perm=sds(lead + (k,), jnp.int32),
        w4=sds(lead + (k_s // 2, n), jnp.uint8),
        s4=sds(lead + (k_s,), jnp.float32),
        z4=sds(lead + (k_s,), jnp.float32),
        bits=sds(lead + (k_b // 8, n), jnp.uint8),
        alpha_s=sds(lead + (n,), jnp.float32),
        alpha_r1=sds(lead + (n,), jnp.float32),
        alpha_r2=sds(lead + (k_b,), jnp.float32),
        k_s=k_s, k=k, n=n, use_kernel=qcfg.use_kernel)


def declare_quantized(cfg: ArchConfig, par: Parallel, qcfg: QuantConfig,
                      rules: Rules, min_dim: int = 256
                      ) -> Tuple[Tree, Tree]:
    """(abstract quantized params, PartitionSpec tree), same structure."""
    declared = M.declare_params(cfg, par)

    def visit(path, leaf):
        if is_quantizable(path, leaf, min_dim):
            q = declare_qlinear(leaf, qcfg)
            spec = qlinear_specs(leaf.axes, q.k_s, q.k, q.n, rules,
                                 use_kernel=qcfg.use_kernel)
            return (q, spec)
        return (jax.ShapeDtypeStruct(leaf.shape, leaf.dtype),
                rules.spec(leaf.axes))

    paired = jax.tree_util.tree_map_with_path(visit, declared, is_leaf=is_p)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and (
        isinstance(x[0], (jax.ShapeDtypeStruct, QLinear)))
    abstract = jax.tree.map(lambda t: t[0], paired, is_leaf=is_pair)
    specs = jax.tree.map(lambda t: t[1], paired, is_leaf=is_pair)
    return abstract, specs
