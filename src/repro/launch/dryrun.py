import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init).  This module is the ONLY place the 512 fake devices exist;
#   smoke tests and benchmarks see the real single CPU device.

"""Multi-pod dry-run (deliverable e) + roofline-term extraction (g).

For every (architecture × input-shape) cell and mesh:

    with mesh:
        jax.jit(step, in_shardings=…, out_shardings=…) \
            .lower(**abstract inputs).compile()

must succeed; we record ``memory_analysis()`` / ``cost_analysis()`` and
the collective traffic parsed from the optimized HLO
(launch/hlo_analysis.py) as JSON under results/dryrun/.

Step kinds per cell (configs/base.SHAPE_CELLS):
    train_4k     -> full train_step (fwd+bwd+AdamW, grad-accum scan)
    prefill_32k  -> prefill (full-seq forward + cache build), PTQ1.61 weights
    decode_32k   -> decode_step (1 token against ring caches), PTQ1.61 weights
    long_500k    -> decode_step at 500k context (sub-quadratic archs only)

Serving cells default to quantized (packed QLinear) weights — the paper's
system-level payoff; ``--serve-fp`` lowers the bf16 variant instead so
§Perf can report the before/after weight-traffic delta.

Usage:
    python -m repro.launch.dryrun --all                 # every live cell, 16x16
    python -m repro.launch.dryrun --all --mesh multipod # 2x16x16
    python -m repro.launch.dryrun --arch qwen3-4b --cell train_4k
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import (ArchConfig, SHAPE_CELLS, ShapeCell,
                                cell_applicable, cell_by_name)
from repro.core.qlinear import QuantConfig
from repro.distributed.compression import CompressionConfig
from repro.distributed.sharding import named_shardings, specs_for_tree
from repro.launch import hlo_analysis as H
from repro.launch.inputs import decode_inputs, prefill_inputs, train_inputs
from repro.launch.mesh import make_production_mesh
from repro.launch.presets import Preset, make_preset
from repro.launch.qdeclare import declare_quantized
from repro.launch.train import make_train_step, state_specs
from repro.models import model as M
from repro.models.param import abstractify
from repro.optim.adamw import AdamW, AdamWState

Tree = Any
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


# ---------------------------------------------------------------------------
# Abstract state builders
# ---------------------------------------------------------------------------
def abstract_train_state(cfg: ArchConfig, par) -> Tree:
    p_abs = abstractify(M.declare_params(cfg, par))
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "params": p_abs,
        "opt": AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                          mu=jax.tree.map(f32, p_abs),
                          nu=jax.tree.map(f32, p_abs)),
        "residual": jax.ShapeDtypeStruct((), jnp.float32),
    }


def serving_params(cfg: ArchConfig, par, rules, quantized: bool,
                   qcfg: QuantConfig) -> Tuple[Tree, Tree]:
    """(abstract params, PartitionSpec tree) for prefill/decode cells."""
    if quantized:
        return declare_quantized(cfg, par, qcfg, rules)
    decl = M.declare_params(cfg, par)
    return abstractify(decl), specs_for_tree(decl, rules)


# ---------------------------------------------------------------------------
# Lower + compile one cell
# ---------------------------------------------------------------------------
def lower_cell(cfg: ArchConfig, cell: ShapeCell, mesh, preset: Preset,
               *, quantized_serving: bool = True,
               qcfg: QuantConfig = QuantConfig()):
    par, rules = preset.par, preset.rules
    opt = AdamW(lr=1e-4)

    with mesh:
        if cell.kind == "train":
            sspec = state_specs(cfg, par, rules, CompressionConfig())
            step = make_train_step(cfg, par, opt, CompressionConfig(),
                                   param_spec=sspec["params"])
            state_abs = abstract_train_state(cfg, par)
            inp, ispec = train_inputs(cfg, cell, par, rules)
            fn = jax.jit(step,
                         in_shardings=(named_shardings(mesh, sspec),
                                       named_shardings(mesh, ispec)),
                         donate_argnums=(0,))
            return fn.lower(state_abs, inp)

        p_abs, pspec = serving_params(cfg, par, rules, quantized_serving,
                                      qcfg)
        if cell.kind == "prefill":
            inp, ispec = prefill_inputs(cfg, cell, par, rules)

            def prefill_step(params, batch):
                return M.prefill(cfg, par, params, batch, cell.seq_len)

            fn = jax.jit(prefill_step,
                         in_shardings=(named_shardings(mesh, pspec),
                                       named_shardings(mesh, ispec)))
            return fn.lower(p_abs, inp)

        # decode
        (tok, pos, caches), (tspec, pspec2, cspec) = decode_inputs(
            cfg, cell, par, rules)

        def serve_step(params, token, position, caches):
            return M.decode_step(cfg, par, params, token, position, caches,
                                 cell.seq_len)

        fn = jax.jit(serve_step,
                     in_shardings=(named_shardings(mesh, pspec),
                                   named_shardings(mesh, tspec),
                                   named_shardings(mesh, pspec2),
                                   named_shardings(mesh, cspec)),
                     donate_argnums=(3,))
        return fn.lower(p_abs, tok, pos, caches)


def analyze(compiled, mesh, cfg: ArchConfig, cell: ShapeCell) -> Dict:
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    # trip-count-aware static analysis (XLA's cost_analysis counts scan
    # bodies once — see hlo_analysis.py docstring)
    mod = H.module_analysis(compiled.as_text())
    coll = mod["collectives"]
    flops = float(mod["flops"])
    bytes_accessed = float(mod["hbm_bytes"])
    roof = H.roofline_terms(flops, bytes_accessed, coll["wire_bytes"])

    # useful-FLOPs model: 6·N_active·D for train, 2·N_active·D for fwd-only
    n_active = cfg.active_params()
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    mult = 6 if cell.kind == "train" else 2
    model_flops = mult * n_active * tokens
    devices = int(mesh.devices.size)
    model_flops_per_dev = model_flops / devices

    top = H.top_contributors(compiled.as_text(), k=5)
    slim = lambda rows: [{k: r[k] for k in
                          ("name", "mult", "flops", "bytes", "coll_wire")}
                         for r in rows]
    return {
        "flops_per_device": flops,
        "bytes_accessed_per_device": bytes_accessed,
        "top": {k: slim(v) for k, v in top.items()},
        "xla_flops_raw": float(ca.get("flops", 0.0)),
        "xla_bytes_raw": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "roofline": roof,
        "model_flops": model_flops,
        "model_flops_per_device": model_flops_per_dev,
        "useful_flops_ratio": (model_flops_per_dev / flops) if flops else 0.0,
        "devices": devices,
    }


def run_cell(arch: str, cell_name: str, mesh_kind: str, *,
             quantized_serving: bool = True, out_dir: str = RESULTS_DIR,
             force: bool = False, tag: str = "") -> Dict:
    cfg = registry.get(arch)
    cell = cell_by_name(cell_name)
    ok, why = cell_applicable(cfg, cell)
    base = f"{arch}__{cell_name}{('__' + tag) if tag else ''}"
    mesh_dir = os.path.join(out_dir, mesh_kind)
    os.makedirs(mesh_dir, exist_ok=True)
    path = os.path.join(mesh_dir, base + ".json")

    if not ok:
        rec = {"arch": arch, "cell": cell_name, "mesh": mesh_kind,
               "status": "skipped", "reason": why}
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        return rec

    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    preset = make_preset(cfg, cell, mesh)
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, cell, mesh, preset,
                             quantized_serving=quantized_serving)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        rec = {
            "arch": arch, "cell": cell_name, "mesh": mesh_kind,
            "status": "ok",
            "quantized_serving": bool(quantized_serving
                                      and cell.kind != "train"),
            "preset": {
                "tp": preset.par.tp, "dp": preset.par.dp,
                "fsdp": preset.par.fsdp, "sp": preset.par.sp,
                "microbatches": preset.par.microbatches,
                "remat": preset.par.remat,
                "shard_batch": preset.par.shard_batch,
                "ep": preset.rules.ep,
            },
            "lower_s": t_lower, "compile_s": t_compile,
            **analyze(compiled, mesh, cfg, cell),
        }
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec = {"arch": arch, "cell": cell_name, "mesh": mesh_kind,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main(argv=None):
    p = argparse.ArgumentParser(description="multi-pod dry-run")
    p.add_argument("--arch", default=None)
    p.add_argument("--cell", default=None)
    p.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    p.add_argument("--all", action="store_true",
                   help="all assigned archs × all applicable cells")
    p.add_argument("--serve-fp", action="store_true",
                   help="bf16 weights for serving cells (baseline variant)")
    p.add_argument("--tag", default="",
                   help="suffix for the result filename (perf variants)")
    p.add_argument("--force", action="store_true")
    p.add_argument("--out", default=RESULTS_DIR)
    args = p.parse_args(argv)

    if args.all:
        archs = registry.ASSIGNED
        cells = [c.name for c in SHAPE_CELLS]
    else:
        archs = [args.arch or "qwen3-4b"]
        cells = [args.cell or "train_4k"]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for cell in cells:
            t0 = time.time()
            rec = run_cell(arch, cell, args.mesh,
                           quantized_serving=not args.serve_fp,
                           out_dir=args.out, force=args.force,
                           tag=args.tag)
            dt = time.time() - t0
            st = rec["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_err += st == "error"
            extra = ""
            if st == "ok":
                r = rec["roofline"]
                extra = (f"dominant={r['dominant']} "
                         f"bound={r['step_time_lower_bound_s']*1e3:.2f}ms "
                         f"compute_frac={r['compute_fraction']:.3f}")
            elif st == "error":
                extra = rec["error"][:120]
            print(f"[{st:7s}] {arch:22s} {cell:12s} mesh={args.mesh:8s} "
                  f"({dt:5.1f}s) {extra}", flush=True)
    print(f"\nok={n_ok} skipped={n_skip} error={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
