"""Per-(arch × shape × mesh) parallelism presets.

Chooses the Parallel knobs and sharding Rules for each dry-run cell:
  * FSDP (ZeRO-3) for ≥8B-parameter archs (weights + opt state shard over
    data as well as model);
  * EP for granite (32 experts / 16-way model axis divides); Mixtral's 8
    experts use expert-TP (ffn over model) instead;
  * gradient-accumulation microbatches scale with d_model so per-chip
    activation memory stays flat at train_4k;
  * batch sharding disabled when global_batch < |dp| (long_500k b=1).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed.sharding import Rules, rules_for_mesh
from repro.models.common import Parallel

FSDP_PARAM_THRESHOLD = 8e9


@dataclass(frozen=True)
class Preset:
    par: Parallel
    rules: Rules
    quantized_serving: bool = True    # serve cells with PTQ1.61 weights


def n_params_cheap(cfg: ArchConfig) -> int:
    # avoid building the tree at preset time: rough closed form is fine
    from repro.models import model as M
    return M.n_params(cfg)


def make_preset(cfg: ArchConfig, cell: ShapeCell, mesh) -> Preset:
    tp = mesh.shape["model"]
    dp = int(mesh.devices.size) // tp
    n = n_params_cheap(cfg)
    fsdp = bool(cell.kind == "train" and n >= FSDP_PARAM_THRESHOLD)
    ep = bool(cfg.moe and cfg.moe.n_experts % tp == 0)
    shard_batch = cell.global_batch % dp == 0 and cell.global_batch >= dp
    if cell.kind == "train":
        micro = 8 if cfg.d_model >= 6144 else (4 if cfg.d_model >= 2048 else 2)
        micro = min(micro, max(1, cell.global_batch // dp))
    else:
        micro = 1
    par = Parallel(tp=tp, dp=dp, fsdp=fsdp, sp=True,
                   microbatches=micro, remat=(cell.kind == "train"),
                   attn_chunk=1024, shard_batch=shard_batch,
                   # decode_unroll measured WORSE (8× bytes): XLA does not
                   # elide the stacked-cache copies that unrolled in-place
                   # updates need — see EXPERIMENTS.md §Perf (refuted)
                   decode_unroll=False)
    rules = dataclasses.replace(rules_for_mesh(mesh, fsdp=fsdp, ep=ep))
    return Preset(par=par, rules=rules)
