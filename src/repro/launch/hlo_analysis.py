"""Static roofline analysis of compiled (SPMD, per-device) HLO text.

XLA's ``compiled.cost_analysis()`` counts every instruction ONCE — a
``jax.lax.scan`` over 36 layers reports 1/36 of the real FLOPs (verified
empirically; see tests/test_hlo_analysis.py).  Since this framework scans
every depth dimension (layers, microbatches, attention chunks), module-
level cost_analysis is useless for a roofline.  This module re-derives
the three roofline inputs from the optimized HLO text with **while-loop
trip counts** (XLA's ``known_trip_count`` backend annotation) multiplied
through the call graph:

* **FLOPs** — ``dot`` instructions: 2·|result|·|contracted dims| from the
  operand shapes (MXU work; elementwise VPU flops are excluded — they are
  never the v5e bottleneck at these shapes);
* **HBM bytes** — Σ (result + operand bytes) over materialized
  instructions (fusion bodies excluded: a fusion reads its operands and
  writes its result once; tuples/bitcasts/parameters excluded like XLA's
  own bytes-accessed);
* **collective bytes** — every ``all-reduce / all-gather / reduce-scatter
  / all-to-all / collective-permute`` (sync or ``-start`` async), with
  operand bytes derived from result shape + group size, and modeled ring
  **wire bytes** (all-reduce 2(g−1)/g·S etc.) — the number a link-level
  roofline actually wants.

Everything here is text parsing — no jax device state — so it runs
identically on the dry-run's 512 fake devices and in unit tests.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*(?P<ret>.*?)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<async>-start)?\(")
_WHILE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*.*?\bwhile\(.*?"
    r"condition=%(?P<cond>[\w.\-]+),\s*body=%(?P<body>[\w.\-]+)")
_CALL_RE = re.compile(r"\b(?:call|async-start)\(.*?to_apply=%(?P<callee>[\w.\-]+)")
_COND_RE = re.compile(r"branch_computations=\{(?P<branches>[^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*([0-9]+)')
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in an HLO type string
    (handles tuples: sums every dtype[dims] group)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = re.search(r"source_target_pairs=\{", line)
    if m:
        return 2  # permute: pairwise
    return 1


@dataclass
class Collective:
    kind: str
    result_bytes: int
    operand_bytes: int
    wire_bytes: int
    group_size: int
    trips: int = 1

    @property
    def total_operand_bytes(self) -> int:
        return self.operand_bytes * self.trips

    @property
    def total_wire_bytes(self) -> int:
        return self.wire_bytes * self.trips


def _derive_bytes(kind: str, result_bytes: int, g: int) -> Tuple[int, int]:
    """(operand_bytes, modeled ring wire bytes per device)."""
    g = max(g, 1)
    if kind == "all-gather":
        op = result_bytes // g
        wire = result_bytes - op            # receive everyone else's shard
    elif kind == "reduce-scatter":
        op = result_bytes * g
        wire = result_bytes * (g - 1)       # send g-1 shards of result size
    elif kind == "all-reduce":
        op = result_bytes
        wire = int(2 * result_bytes * (g - 1) / g)
    elif kind == "all-to-all":
        op = result_bytes
        wire = int(result_bytes * (g - 1) / g)
    else:  # collective-permute: one send + one recv of the buffer
        op = result_bytes
        wire = result_bytes
    return op, wire


@dataclass
class _Computation:
    name: str
    collectives: List[Collective] = field(default_factory=list)
    # (callee, multiplier) edges: while bodies get trip_count, others 1
    calls: List[Tuple[str, int]] = field(default_factory=list)
    flops: float = 0.0          # dot/conv flops of this body (once)
    hbm_bytes: float = 0.0      # materialized result+operand bytes (once)
    is_fusion_body: bool = False


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    header = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
    for line in hlo.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = header.match(line.strip())
            cur = m.group(1) if m else None
            if cur is not None:
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    return m.group(1) if m else None


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<ret>\([^)]*\)|\S+)\s+(?P<op>[\w\-]+)"
    r"\((?P<args>[^)]*)\)")
_DIMS_RE = re.compile(r"\[([0-9,]*)\]")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_TF_COND_RE = re.compile(
    r"true_computation=%([\w.\-]+),\s*false_computation=%([\w.\-]+)")

# instructions that are free / metadata-only for HBM-byte accounting
_FREE_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "while", "call", "conditional",
    "partition-id", "replica-id", "opt-barrier", "domain",
})
_ASYNC_DONE = frozenset({
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "all-to-all-done", "copy-done", "async-done", "async-update",
    "send-done", "recv-done",
})


def _type_dims(type_str: str) -> List[int]:
    m = _DIMS_RE.search(type_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


def _fusion_io_costs(lines: List[str]) -> Tuple[Dict[int, Optional[int]],
                                                Optional[int]]:
    """Effective I/O bytes of a fused computation.

    A fusion reads its operands and writes its result ONCE — except when a
    parameter is only ever dynamic-sliced (scan reading one layer of a
    stacked buffer: the fusion reads just the slice) or the root is a
    dynamic-update-slice / scatter (scan carry or cache update: writes
    just the slice).  Counting full buffers here overcounts stacked-
    parameter reads by L×.

    Dtype-normalization converts are treated as TRANSPARENT when tracking
    a buffer from parameter to slice op: XLA *CPU* promotes bf16
    scatter/DUS through full-buffer f32 converts (float normalization),
    which a TPU build would not emit — following the buffer through
    convert/copy/bitcast keeps the analysis TPU-faithful.

    Returns ({param_index: bytes or None=full}, result_bytes or None=full).
    """
    _TRANSPARENT = ("convert", "copy", "bitcast", "reshape")
    types: Dict[str, str] = {}
    param_of: Dict[str, int] = {}
    uses: Dict[str, List[Tuple[str, List[str]]]] = defaultdict(list)
    instr_op: Dict[str, str] = {}
    instr_args: Dict[str, List[str]] = {}
    root: Optional[str] = None
    for line in lines:
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, ret, op, args = (mi.group("name"), mi.group("ret"),
                               mi.group("op"), mi.group("args"))
        types[name] = ret
        instr_op[name] = op
        operands = re.findall(r"%([\w.\-]+)", args)
        instr_args[name] = operands
        for o in operands:
            uses[o].append((op, operands))
        if op == "parameter":
            m = re.match(r"\s*(\d+)", args)
            if m:
                param_of[name] = int(m.group(1))
        if line.lstrip().startswith("ROOT"):
            root = name
    if root is None and lines:
        for line in reversed(lines):
            mi = _INSTR_RE.match(line)
            if mi:
                root = mi.group("name")
                break

    def alias_set(pname: str) -> set:
        """pname plus every transparent-unary instruction fed (only) by it."""
        al = {pname}
        changed = True
        while changed:
            changed = False
            for iname, op in instr_op.items():
                if (iname not in al and op in _TRANSPARENT and
                        instr_args.get(iname) and
                        instr_args[iname][0] in al):
                    al.add(iname)
                    changed = True
        return al

    param_costs: Dict[int, Optional[int]] = {}
    for pname, idx in param_of.items():
        al = alias_set(pname)
        ext_uses = []   # uses of any alias member outside the alias chain
        for member in al:
            for iname, op in instr_op.items():
                if iname in al:
                    continue
                ops = instr_args.get(iname, [])
                for pos, o in enumerate(ops):
                    if o == member:
                        ext_uses.append((op, pos, iname))
        if ext_uses and all(op == "dynamic-slice" and pos == 0
                            for op, pos, _ in ext_uses):
            param_costs[idx] = sum(shape_bytes(types.get(iname, ""))
                                   for op, pos, iname in ext_uses)
        elif ext_uses and all(op in ("dynamic-update-slice", "scatter")
                              and pos == 0 for op, pos, _ in ext_uses):
            param_costs[idx] = 0    # passed-through carry buffer
        elif not ext_uses and root in al:
            param_costs[idx] = 0    # pure pass-through to the root
        else:
            param_costs[idx] = None  # full read

    def elem_cost(name: str) -> Optional[int]:
        # walk back through transparent unaries to the slice-updating op
        seen = 0
        while (instr_op.get(name) in _TRANSPARENT and
               instr_args.get(name) and seen < 8):
            name = instr_args[name][0]
            seen += 1
        op = instr_op.get(name)
        ops = instr_args.get(name, [])
        if op == "dynamic-update-slice":
            if len(ops) > 1 and ops[1] in types:
                return shape_bytes(types[ops[1]])   # writes the slice
        if op == "scatter":
            if len(ops) > 2 and ops[2] in types:
                return 2 * shape_bytes(types[ops[2]])
        return None

    result_cost: Optional[int] = None
    if root is not None:
        if instr_op.get(root) == "tuple":
            total, any_special = 0, False
            for o in instr_args.get(root, []):
                c = elem_cost(o)
                if c is None:
                    total += shape_bytes(types.get(o, ""))
                else:
                    any_special = True
                    total += c
            result_cost = total if any_special else None
        else:
            result_cost = elem_cost(root)
    return param_costs, result_cost


VMEM_RESIDENT_LIMIT = 64 * 1024 * 1024   # invariant operands ≤ this stay
                                         # in VMEM across loop iterations


def _loop_invariant_names(lines: List[str]) -> set:
    """Names (incl. transparent-unary aliases) that a while BODY carries
    through unchanged: tuple elements whose ROOT position is the
    pass-through of the same GTE index.  A TPU build keeps such operands
    (weights of a sequential scan) resident in VMEM — charging their full
    size per iteration overstates HBM traffic by the trip count."""
    gte_idx: Dict[str, int] = {}
    alias_src: Dict[str, str] = {}
    root_ops: List[str] = []
    for line in lines:
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, op, args = mi.group("name"), mi.group("op"), mi.group("args")
        operands = re.findall(r"%([\w.\-]+)", args)
        if op == "get-tuple-element":
            mo = re.search(r"index=(\d+)", line)
            if mo and operands:
                gte_idx[name] = int(mo.group(1))
        if op in ("convert", "copy", "bitcast", "reshape") and operands:
            alias_src[name] = operands[0]
        if line.lstrip().startswith("ROOT") and op == "tuple":
            root_ops = operands

    def resolve(n: str) -> str:
        seen = 0
        while n in alias_src and seen < 8:
            n = alias_src[n]
            seen += 1
        return n

    invariant_idx = {i for i, o in enumerate(root_ops)
                     if gte_idx.get(resolve(o)) == i}
    inv = {n for n, i in gte_idx.items() if i in invariant_idx}
    # transparent closure
    changed = True
    while changed:
        changed = False
        for n, src in alias_src.items():
            if src in inv and n not in inv:
                inv.add(n)
                changed = True
    return inv


def parse_module(hlo: str) -> Dict[str, _Computation]:
    """Full per-computation analysis: collectives, dot FLOPs, HBM bytes,
    call edges.  Fusion bodies contribute FLOPs but not bytes (their I/O
    is charged at the fusion boundary, slice-aware)."""
    split = _split_computations(hlo)
    fusion_bodies = set(_CALLS_RE.findall(hlo))
    fusion_costs = {name: _fusion_io_costs(lines)
                    for name, lines in split.items()
                    if name in fusion_bodies}
    comps: Dict[str, _Computation] = {}

    for name, lines in split.items():
        c = _Computation(name, is_fusion_body=(name in fusion_bodies))
        invariant = _loop_invariant_names(lines)
        types: Dict[str, str] = {}

        def op_bytes(o: str) -> int:
            """Operand read cost: loop-invariant VMEM-resident = free."""
            if o not in types:
                return 0
            b = shape_bytes(types[o])
            if o in invariant and b <= VMEM_RESIDENT_LIMIT:
                return 0
            return b

        for line in lines:
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            iname, ret, op, args = (mi.group("name"), mi.group("ret"),
                                    mi.group("op"), mi.group("args"))
            types[iname] = ret
            operands = re.findall(r"%([\w.\-]+)", args)

            # ---- FLOPs: dot_general ------------------------------------
            if op == "dot" and operands:
                lhs_t = types.get(operands[0])
                if lhs_t is not None:
                    lhs_dims = _type_dims(lhs_t)
                    mc = _LHS_C_RE.search(line)
                    contracted = 1
                    if mc and mc.group(1):
                        for d in mc.group(1).split(","):
                            di = int(d)
                            if di < len(lhs_dims):
                                contracted *= lhs_dims[di]
                    out_elems = 1
                    for d in _type_dims(ret):
                        out_elems *= d
                    c.flops += 2.0 * out_elems * contracted

            # ---- collectives -------------------------------------------
            mcoll = _COLL_RE.match(line)
            if mcoll:
                rb = shape_bytes(mcoll.group("ret"))
                g = _group_size(line)
                opb, wire = _derive_bytes(mcoll.group("kind"), rb, g)
                c.collectives.append(Collective(
                    mcoll.group("kind"), rb, opb, wire, g))

            # ---- HBM bytes ---------------------------------------------
            if op not in _FREE_OPS and op not in _ASYNC_DONE:
                if op == "dynamic-update-slice":
                    # in-place: read+write the updated slice only (operand 1)
                    upd = (shape_bytes(types[operands[1]])
                           if len(operands) > 1 and operands[1] in types
                           else 0)
                    b = 2 * upd
                elif op in ("dynamic-slice", "gather"):
                    # reads only the sliced/gathered elements
                    b = 2 * shape_bytes(ret)
                elif op == "scatter":
                    upd = (shape_bytes(types[operands[2]])
                           if len(operands) > 2 and operands[2] in types
                           else shape_bytes(ret))
                    b = 2 * upd
                elif op == "fusion":
                    callee = _CALLS_RE.search(line)
                    pcosts, rcost = fusion_costs.get(
                        callee.group(1) if callee else "", ({}, None))
                    b = shape_bytes(ret) if rcost is None else rcost
                    for i, o in enumerate(operands):
                        if o not in types:
                            continue
                        pc = pcosts.get(i, None)
                        b += op_bytes(o) if pc is None else pc
                else:
                    b = shape_bytes(ret)
                    for o in operands:
                        b += op_bytes(o)
                c.hbm_bytes += b

            # ---- call edges --------------------------------------------
            mw = _WHILE_RE.match(line)
            if mw:
                mt = _TRIP_RE.search(line)
                trips = int(mt.group(1)) if mt else 1
                c.calls.append((mw.group("body"), trips))
                c.calls.append((mw.group("cond"), trips + 1))
                continue
            if op in ("call", "fusion", "reduce", "map", "sort", "scatter",
                      "reduce-window", "select-and-scatter", "async-start",
                      "all-reduce", "all-reduce-start", "reduce-scatter"):
                ma = _TOAPPLY_RE.search(line) or _CALLS_RE.search(line)
                if ma:
                    c.calls.append((ma.group(1), 1))
            if op == "conditional":
                mc2 = _COND_RE.search(line)
                if mc2:
                    for b in mc2.group("branches").split(","):
                        b = b.strip().lstrip("%")
                        if b:
                            c.calls.append((b, 1))
                mtf = _TF_COND_RE.search(line)
                if mtf:
                    c.calls.append((mtf.group(1), 1))
                    c.calls.append((mtf.group(2), 1))
        comps[name] = c
    return comps


def module_analysis(hlo: str) -> Dict:
    """Trip-count-aware per-device totals for the compiled module:
    {flops, hbm_bytes, collectives:{...}}."""
    comps = parse_module(hlo)
    entry = _entry_name(hlo)
    per_kind: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "operand_bytes": 0, "wire_bytes": 0})
    tot = {"flops": 0.0, "hbm_bytes": 0.0}

    def visit(name: str, mult: float, depth: int = 0):
        if name not in comps or depth > 64 or mult <= 0:
            return
        c = comps[name]
        tot["flops"] += c.flops * mult
        if not c.is_fusion_body:
            tot["hbm_bytes"] += c.hbm_bytes * mult
        for col in c.collectives:
            k = per_kind[col.kind]
            k["count"] += mult
            k["operand_bytes"] += col.operand_bytes * mult
            k["wire_bytes"] += col.wire_bytes * mult
        for callee, trips in c.calls:
            visit(callee, mult * trips, depth + 1)

    if entry:
        visit(entry, 1)
    return {
        "flops": tot["flops"],
        "hbm_bytes": tot["hbm_bytes"],
        "collectives": {
            "per_kind": {k: dict(v) for k, v in sorted(per_kind.items())},
            "operand_bytes": int(sum(k["operand_bytes"]
                                     for k in per_kind.values())),
            "wire_bytes": int(sum(k["wire_bytes"]
                                  for k in per_kind.values())),
            "n_collectives": int(sum(k["count"]
                                     for k in per_kind.values())),
        },
    }


def collective_summary(hlo: str) -> Dict:
    """Back-compat wrapper: just the collective block of module_analysis."""
    return module_analysis(hlo)["collectives"]


def _multipliers(hlo: str) -> Tuple[Dict[str, _Computation], Dict[str, float]]:
    comps = parse_module(hlo)
    entry = _entry_name(hlo)
    mults: Dict[str, float] = defaultdict(float)

    def visit(name, mult, depth=0):
        if name not in comps or depth > 64:
            return
        mults[name] += mult
        for callee, trips in comps[name].calls:
            visit(callee, mult * trips, depth + 1)

    if entry:
        visit(entry, 1)
    return comps, mults


def top_contributors(hlo: str, k: int = 12) -> Dict[str, List]:
    """The §Perf drill-down: which computations dominate each roofline
    term (flops / HBM bytes / collective wire bytes), trip-weighted."""
    comps, mults = _multipliers(hlo)
    rows = []
    for name, c in comps.items():
        m = mults.get(name, 0)
        if m == 0:
            continue
        coll = sum(x.wire_bytes for x in c.collectives)
        rows.append({
            "name": name, "mult": m,
            "flops": c.flops * m,
            "bytes": (0 if c.is_fusion_body else c.hbm_bytes) * m,
            "coll_wire": coll * m,
            "coll_ops": [(x.kind, x.operand_bytes, x.group_size)
                         for x in c.collectives[:8]],
        })
    return {
        "by_flops": sorted(rows, key=lambda r: -r["flops"])[:k],
        "by_bytes": sorted(rows, key=lambda r: -r["bytes"])[:k],
        "by_coll": sorted(rows, key=lambda r: -r["coll_wire"])[:k],
    }


def instruction_bytes(hlo: str, comp_name: str, k: int = 15) -> List[Tuple]:
    """Top byte-weighted instructions inside one computation (drill-down
    one level deeper than top_contributors)."""
    split = _split_computations(hlo)
    lines = split.get(comp_name, [])
    fusion_bodies = set(_CALLS_RE.findall(hlo))
    fusion_costs = {n: _fusion_io_costs(ls) for n, ls in split.items()
                    if n in fusion_bodies}
    invariant = _loop_invariant_names(lines)
    types: Dict[str, str] = {}

    def op_bytes(o: str) -> int:
        if o not in types:
            return 0
        b = shape_bytes(types[o])
        if o in invariant and b <= VMEM_RESIDENT_LIMIT:
            return 0
        return b

    out = []
    for line in lines:
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, ret, op, args = (mi.group("name"), mi.group("ret"),
                               mi.group("op"), mi.group("args"))
        types[name] = ret
        operands = re.findall(r"%([\w.\-]+)", args)
        if op in _FREE_OPS or op in _ASYNC_DONE:
            continue
        if op == "dynamic-update-slice":
            b = 2 * (shape_bytes(types[operands[1]])
                     if len(operands) > 1 and operands[1] in types else 0)
        elif op in ("dynamic-slice", "gather"):
            b = 2 * shape_bytes(ret)
        elif op == "fusion":
            callee = _CALLS_RE.search(line)
            pcosts, rcost = fusion_costs.get(
                callee.group(1) if callee else "", ({}, None))
            b = shape_bytes(ret) if rcost is None else rcost
            for i, o in enumerate(operands):
                if o in types:
                    pc = pcosts.get(i, None)
                    b += op_bytes(o) if pc is None else pc
        else:
            b = shape_bytes(ret) + sum(op_bytes(o) for o in operands)
        mo = re.search(r'op_name="([^"]*)"', line)
        out.append((b, op, ret[:48], (mo.group(1)[-80:] if mo else "")))
    out.sort(reverse=True)
    return out[:k]


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e constants — DESIGN.md §6)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per chip, one direction)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   *, peak=PEAK_FLOPS, hbm=HBM_BW, ici=ICI_BW) -> Dict:
    """Three per-device roofline times (seconds) + the dominant term.

    Inputs are PER-DEVICE quantities (cost_analysis of the SPMD module and
    the per-device collective summary), so no further chip division.
    """
    t_compute = flops / peak
    t_memory = hbm_bytes / hbm
    t_collective = coll_bytes / ici
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_collective)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "step_time_lower_bound_s": bound,
        # fraction of the bound spent doing useful math — the roofline score
        "compute_fraction": t_compute / bound if bound > 0 else 0.0,
    }
