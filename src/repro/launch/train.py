"""Training launcher: pjit'd train step with gradient accumulation,
fault-tolerant checkpoint/restart, failure injection, elastic re-mesh,
straggler watchdog and optional gradient compression.

CPU-runnable end-to-end driver (deliverable b):

    PYTHONPATH=src python -m repro.launch.train --arch tiny-lm --steps 200

On a real fleet the same module runs under the production mesh
(``--mesh pod|multipod`` — the dry-run proves those shardings compile);
the single-process container trains reduced configs on a (1,1) mesh.

Fault-tolerance path (tests/test_fault_tolerance.py):
    --fail-at-step 30 --save-every 10 --restore auto
injects a failure at step 30; the Supervisor restores step 20 and
re-runs.  Training is bit-deterministic across restarts because the data
stream is a pure function of the step counter.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.distributed.compression import (CompressionConfig, compress,
                                           init_residual, wire_bytes)
from repro.distributed.fault import (FailureInjector, InjectedFailure,
                                     StragglerWatchdog, Supervisor)
from repro.distributed.sharding import (Rules, named_shardings,
                                        rules_for_mesh, specs_for_tree)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.models.common import Parallel
from repro.models.param import P, is_leaf as is_p, tree_map_params
from repro.optim.adamw import AdamW, AdamWState, cosine_schedule

Tree = Any


# ---------------------------------------------------------------------------
# Train state & step
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, par: Parallel, opt: AdamW,
                    ccfg: CompressionConfig, param_spec: Optional[Tree] = None):
    """(state, batch) -> (state, metrics).  Gradient accumulation over
    ``par.microbatches`` via lax.scan keeps activation memory flat; the
    compressor (error-feedback int8/top-k) runs on the averaged gradient
    (EF equivalence — distributed/compression.py).

    ``param_spec`` (the params' PartitionSpec tree) shards the gradient
    ACCUMULATOR like the parameters (ZeRO-2): without it GSPMD keeps the
    accumulator replicated and emits a full f32 gradient all-reduce per
    microbatch — measured 8× the necessary gradient traffic on the FSDP
    archs (command-r/llava/mixtral train_4k, §Perf)."""

    def loss_fn(params, batch):
        return M.forward_loss(cfg, par, params, batch)

    def train_step(state, batch):
        params, opt_state, residual = (state["params"], state["opt"],
                                       state["residual"])
        mb = par.microbatches
        if mb > 1:
            b = batch["tokens"].shape[0]
            assert b % mb == 0, (b, mb)
            split = {k: v.reshape((mb, b // mb) + v.shape[1:])
                     for k, v in batch.items()}
            # without this constraint the partitioner factors the data axis
            # across (micro, batch) dims — each microbatch ends up only
            # dp/mb-way sharded, wasting mb× compute (found via the
            # roofline dry-run; see EXPERIMENTS.md §Perf)
            from jax.sharding import PartitionSpec as PS
            from repro.models.common import _batch_axes, in_mesh
            if in_mesh():
                split = {
                    k: jax.lax.with_sharding_constraint(
                        v, PS(None, _batch_axes(),
                              *([None] * (v.ndim - 2))))
                    for k, v in split.items()}

            def micro(carry, mbatch):
                loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
                acc_l, acc_g = carry
                return (acc_l + loss,
                        jax.tree.map(jnp.add, acc_g, grads)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            from repro.models.common import in_mesh
            if param_spec is not None and in_mesh():
                zeros = jax.tree.map(
                    jax.lax.with_sharding_constraint, zeros, param_spec)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zeros), split)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if ccfg.kind is not None:
            grads, residual = compress(grads, residual, ccfg)
        params, opt_state = opt.update(grads, opt_state, params)
        new_state = {"params": params, "opt": opt_state,
                     "residual": residual}
        return new_state, {"loss": loss}

    return train_step


def init_state(cfg: ArchConfig, par: Parallel, opt: AdamW,
               ccfg: CompressionConfig, seed: int = 0) -> Tree:
    params = M.init_params(cfg, par, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    residual = (init_residual(params) if ccfg.kind is not None
                else jnp.zeros((), jnp.float32))
    return {"params": params, "opt": opt_state, "residual": residual}


def state_specs(cfg: ArchConfig, par: Parallel, rules: Rules,
                ccfg: CompressionConfig) -> Tree:
    """PartitionSpec tree matching init_state's structure."""
    declared = M.declare_params(cfg, par)
    pspec = specs_for_tree(declared, rules)
    from jax.sharding import PartitionSpec as PS
    ospec = AdamWState(step=PS(), mu=pspec, nu=pspec)
    rspec = pspec if ccfg.kind is not None else PS()
    return {"params": pspec, "opt": ospec, "residual": rspec}


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def build_mesh(kind: str):
    if kind == "host":
        return make_host_mesh()
    return make_production_mesh(multi_pod=(kind == "multipod"))


def run(args) -> Dict[str, Any]:
    mesh = build_mesh(args.mesh)
    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, vocab=min(cfg.vocab, 512))
    tp = mesh.shape["model"]
    dp = int(mesh.devices.size) // tp
    par = Parallel(tp=tp, dp=dp, microbatches=args.microbatches,
                   remat=args.remat, attn_chunk=args.attn_chunk,
                   sp=tp > 1)
    rules = rules_for_mesh(mesh, fsdp=args.fsdp)
    ccfg = CompressionConfig(kind=args.compression,
                             topk_frac=args.topk_frac)
    opt = AdamW(lr=args.lr, weight_decay=0.01, clip_norm=1.0,
                schedule=cosine_schedule(warmup=args.warmup,
                                         total=args.steps))
    pspec = specs_for_tree(M.declare_params(cfg, par), rules)
    step_fn = make_train_step(cfg, par, opt, ccfg, param_spec=pspec)

    corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab, seed=args.seed))

    def batch_at(step: int) -> Dict[str, jax.Array]:
        tok, tgt = next(corpus.batches(args.batch, args.seq, 1,
                                       split="train", host=step,
                                       n_hosts=1 << 30))
        return {"tokens": jnp.asarray(tok), "targets": jnp.asarray(tgt)}

    with mesh:
        state = init_state(cfg, par, opt, ccfg, seed=args.seed)
        sspec = state_specs(cfg, par, rules, ccfg)
        from jax.sharding import PartitionSpec as PS
        bspec = {"tokens": PS(rules.dp_axes if dp > 1 else None),
                 "targets": PS(rules.dp_axes if dp > 1 else None)}
        jstep = jax.jit(step_fn,
                        in_shardings=(named_shardings(mesh, sspec),
                                      named_shardings(mesh, bspec)),
                        out_shardings=(named_shardings(mesh, sspec), None),
                        donate_argnums=(0,))

        start = 0
        if args.restore == "auto" and args.ckpt_dir and \
                latest_step(args.ckpt_dir) is not None:
            state, start = restore_checkpoint(args.ckpt_dir, state)
            print(f"[restore] resumed from step {start}")

        injector = FailureInjector(tuple(args.fail_at_step or ()))
        watchdog = StragglerWatchdog()
        losses = []

        def restore() -> int:
            nonlocal state
            state, s = restore_checkpoint(args.ckpt_dir, state)
            return s

        def one_step(step: int):
            nonlocal state
            injector.maybe_fail(step)
            t0 = time.time()
            state, metrics = jstep(state, batch_at(step))
            loss = float(metrics["loss"])
            losses.append(loss)
            watchdog.observe(step, time.time() - t0)
            if step % args.log_every == 0:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"({(time.time()-t0)*1e3:.0f} ms)")
            # checkpoint label = steps COMPLETED, so restore resumes at the
            # next step (no double-applied update after a restart)
            if args.ckpt_dir and (step + 1) % args.save_every == 0:
                save_checkpoint(args.ckpt_dir, step + 1, state)

        sup = Supervisor(restore, max_restarts=args.max_restarts)
        sup.run(one_step, start, args.steps)

        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps, state)

    out = {"final_loss": losses[-1] if losses else None,
           "first_loss": losses[0] if losses else None,
           "restarts": sup.restarts,
           "straggler_steps": watchdog.slow_steps,
           "wire_bytes": wire_bytes(state["params"], ccfg)}
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
    return out


def parse_args(argv=None):
    p = argparse.ArgumentParser(description="repro training launcher")
    p.add_argument("--arch", default="tiny-lm")
    p.add_argument("--reduced", action="store_true",
                   help="train the reduced same-family config (CPU scale)")
    p.add_argument("--mesh", default="host",
                   choices=["host", "pod", "multipod"])
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--attn-chunk", type=int, default=1024)
    p.add_argument("--remat", action="store_true")
    p.add_argument("--fsdp", action="store_true")
    p.add_argument("--compression", default=None,
                   choices=[None, "int8", "topk"])
    p.add_argument("--topk-frac", type=float, default=0.1)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--save-every", type=int, default=50)
    p.add_argument("--restore", default="none", choices=["none", "auto"])
    p.add_argument("--fail-at-step", type=int, nargs="*", default=None)
    p.add_argument("--max-restarts", type=int, default=3)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json-out", default=None)
    return p.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
