"""Serving launcher: quantize a model with PTQ1.61, run the continuous-
batching engine over a stream of requests (deliverable b, serving flavor).

    PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm --requests 8

Weights are quantized data-free (fast path) or with the full calibrated
pipeline (--calibrated).  ``--kernel`` dispatches the fused Pallas
mixed_matmul (interpret mode on CPU) instead of the XLA dequant path.
``--paged`` serves from the paged KV cache (block-table allocator +
priority-class/preemption scheduler; see repro.runtime.paged_cache) with
``--page-size`` tokens per page and a ``--pool-pages`` global budget;
paged decode attention runs through the Pallas flash-decode kernel on
feasible shapes (``--no-paged-kernel`` pins the XLA dense-gather
reference path).

Event-loop extras (this is the end-to-end demo of the engine's typed
event API):

  * ``--stream`` drives ``Engine.tick()`` directly and prints every
    ``TokenEvent`` the tick it is emitted (rid, output index, token) —
    no buffering until completion.
  * ``--cancel-after-s N`` cancels the longest-running in-flight
    request (earliest admitted, still decoding) once N seconds of
    serving have elapsed; the JSON output records the cancelled rids
    and how many pool pages each cancellation freed (same tick).
  * ``--priority a,b,c`` cycles the listed priority classes across the
    submitted requests (weighted-deficit admission with aging:
    realtime=8 / standard=4 / batch=1 by default); per-class TTFT/TBT
    land in the engine-metrics JSON.
  * ``--share-prefix`` enables copy-on-write prefix sharing
    (``Engine(prefix_sharing=True)``) and gives all requests a common
    page-aligned prompt prefix so the sharing is visible: the common
    pages are allocated once, and the JSON carries the prefix-cache
    counters (hits, pages attached instead of allocated, COW copies).

Engine metrics (tokens/s, TTFT, TBT p50/p95 overall and per class,
queue depth, page utilization) are included in the JSON output either
way.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.bits import model_bits
from repro.core.pipeline import (quantize_model_ptq161,
                                 quantize_params_data_free)
from repro.core.qlinear import QuantConfig
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import model as M
from repro.models.common import Parallel
from repro.runtime.engine import Engine
from repro.runtime.events import FinishEvent, TokenEvent

Tree = Any


def _drive(engine: Engine, *, stream: bool, cancel_after_s=None):
    """Event-API consumer over ``Engine.run(on_tick=...)``: drain the
    queue after every tick, print tokens when streaming, fire the demo
    cancellation once its deadline passes.  Returns the cancellation
    receipts.  The loop itself — stall guard, max_ticks runaway bound —
    stays in the engine."""
    q = engine.event_queue()
    cancelled = []
    state = {"did_cancel": False, "t0": time.time()}

    def after_tick():
        if cancel_after_s is not None and not state["did_cancel"] and \
                time.time() - state["t0"] >= cancel_after_s:
            active = engine.running()
            if active:
                # longest-running = earliest SUBMITTED still in a slot
                # (admit_seq is re-stamped on preemption resumes; rid
                # preserves the original order)
                _, victim = min(active, key=lambda sr: sr[1].rid)
                engine.cancel(victim.rid)
                state["did_cancel"] = True
        while q:
            ev = q.popleft()
            if isinstance(ev, TokenEvent) and stream:
                print(f"[stream] rid={ev.rid} idx={ev.index} "
                      f"tok={ev.token}", flush=True)
            elif isinstance(ev, FinishEvent) and ev.reason == "cancelled":
                cancelled.append({"rid": ev.rid, "tick": ev.tick,
                                  "tokens_before_cancel": ev.n_tokens,
                                  "freed_pages": ev.freed_pages})
                if stream:
                    print(f"[cancel] rid={ev.rid} freed_pages="
                          f"{ev.freed_pages}", flush=True)

    engine.run(on_tick=after_tick)
    after_tick()        # events from the final tick's teardown
    return cancelled


def run(args):
    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    par = Parallel(remat=False, attn_chunk=args.attn_chunk)
    params = M.init_params(cfg, par, jax.random.PRNGKey(args.seed))

    qcfg = QuantConfig(ratio=args.ratio, multiple=args.multiple,
                       steps=args.opt_steps, use_kernel=args.kernel)
    corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab, seed=args.seed))

    t0 = time.time()
    if args.quantize == "none":
        qparams = params
    elif args.quantize == "calibrated":
        if args.fused:
            print("[warn] --fused ignored for calibrated quantization "
                  "(per-projection QLinears cannot be fused post-hoc)")
        calib = [{"tokens": jnp.asarray(t)} for t, _ in
                 corpus.batches(1, args.calib_seq, args.calib_segments,
                                split="calib")]
        qparams = quantize_model_ptq161(cfg, par, params, calib, qcfg,
                                        min_dim=args.min_dim)
    else:  # data-free
        qparams = quantize_params_data_free(params, qcfg,
                                            min_dim=args.min_dim,
                                            fuse=args.fused)
    t_quant = time.time() - t0

    if args.quantize != "none":
        rep = model_bits(qparams)
        print(f"[quant] {args.quantize} in {t_quant:.1f}s — "
              f"{rep['avg_bits_per_quantized_weight']:.3f} bits/weight over "
              f"{rep['quantized_weights']:,} weights")

    if args.share_prefix and not args.paged:
        raise SystemExit("--share-prefix requires --paged "
                         "(sharing lives in the page allocator)")
    if args.chunked_prefill and not args.paged:
        raise SystemExit("--chunked-prefill requires --paged "
                         "(chunks scatter into pool pages)")
    if args.prefix_retain and not args.share_prefix:
        raise SystemExit("--prefix-retain requires --share-prefix "
                         "(retention extends the prefix cache)")
    engine = Engine(cfg, par, qparams, n_slots=args.slots,
                    max_seq=args.max_seq,
                    prefill_buckets=(args.max_seq // 8, args.max_seq // 2),
                    paged=args.paged, page_size=args.page_size,
                    pool_pages=args.pool_pages,
                    paged_kernel=not args.no_paged_kernel,
                    prefix_sharing=args.share_prefix,
                    prefix_retain_pages=args.prefix_retain,
                    chunked_prefill=args.chunked_prefill,
                    prefill_chunk=args.prefill_chunk,
                    fuse_projections=args.fused and args.quantize == "none")

    classes = [c.strip() for c in args.priority.split(",") if c.strip()]
    if not classes:
        raise SystemExit("--priority needs at least one class name "
                         "(e.g. --priority realtime,batch)")
    for c in classes:
        if not engine.scheduler.has_class(c):
            raise SystemExit(f"unknown priority class {c!r}; configured: "
                             f"{sorted(engine.scheduler.cfg.class_weights)}")

    rng = np.random.default_rng(args.seed)
    # --share-prefix: a page-aligned common document prefix (half the
    # prompt budget) + per-request unique tails — the sharing workload
    common_len = 0
    common = np.zeros((0,), np.int32)
    if args.share_prefix:
        common_len = (args.max_seq // 8) // args.page_size * args.page_size
        common = corpus.document(9_999, max(common_len, args.page_size))
        common_len = len(common)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, args.max_seq // 4))
        tail = corpus.document(10_000 + i, plen)
        prompt = np.concatenate([common, tail]) if common_len else tail
        reqs.append(engine.submit(prompt, max_new=args.max_new,
                                  temperature=args.temperature,
                                  deadline_s=args.deadline_s,
                                  priority=classes[i % len(classes)]))

    t0 = time.time()
    if args.stream or args.cancel_after_s is not None:
        cancelled = _drive(engine, stream=args.stream,
                           cancel_after_s=args.cancel_after_s)
    else:
        engine.run()
        cancelled = []      # nothing cancels on the plain run() path
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    out = {
        "requests": len(reqs),
        "generated_tokens": toks,
        "wall_s": dt,
        "tokens_per_s": toks / max(dt, 1e-9),
        "all_done": all(r.done for r in reqs),
        "cancelled": cancelled,
        "priority_classes": classes,
        "quantize_mode": args.quantize,
        "quantize_s": t_quant,
        "cache_backend": engine.backend.name,
        "prefix_sharing": engine.prefix_stats(),
        "engine_metrics": engine.metrics.snapshot(),
    }
    print(json.dumps(out, indent=2))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
    return out


def parse_args(argv=None):
    p = argparse.ArgumentParser(description="repro serving launcher")
    p.add_argument("--arch", default="tiny-lm")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--quantize", default="datafree",
                   choices=["none", "datafree", "calibrated"])
    p.add_argument("--kernel", action="store_true",
                   help="use the fused Pallas mixed_matmul path")
    p.add_argument("--fused", action="store_true",
                   help="N-fuse QKV / gate+up projections (decode fast "
                        "path): fused packed layouts for data-free "
                        "quantization, fp concat fusion for --quantize none")
    p.add_argument("--ratio", type=float, default=0.2)
    p.add_argument("--multiple", type=int, default=16)
    p.add_argument("--min-dim", type=int, default=32)
    p.add_argument("--opt-steps", type=int, default=3)
    p.add_argument("--calib-segments", type=int, default=4)
    p.add_argument("--calib-seq", type=int, default=64)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--paged", action="store_true",
                   help="paged KV cache (block tables + shared page pool)")
    p.add_argument("--page-size", type=int, default=16,
                   help="tokens per KV page (paged mode)")
    p.add_argument("--pool-pages", type=int, default=None,
                   help="total pages in the pool (default: full parity "
                        "with the contiguous layout, slots*max_seq/page)")
    p.add_argument("--no-paged-kernel", action="store_true",
                   help="pin paged decode attention to the XLA-gather "
                        "reference path instead of the Pallas "
                        "flash-decode kernel")
    p.add_argument("--stream", action="store_true",
                   help="drive tick() directly and print every token "
                        "the tick it is emitted (event API demo)")
    p.add_argument("--cancel-after-s", type=float, default=None,
                   help="after N seconds of serving, cancel the longest-"
                        "running in-flight request (its pages free the "
                        "same tick; receipts land in the JSON)")
    p.add_argument("--priority", default="standard",
                   help="comma list of priority classes cycled across "
                        "requests (realtime/standard/batch)")
    p.add_argument("--share-prefix", action="store_true",
                   help="copy-on-write prefix sharing + a common page-"
                        "aligned prompt prefix across requests (paged "
                        "mode only)")
    p.add_argument("--prefix-retain", type=int, default=0,
                   help="retain up to N freed prefix pages in an LRU "
                        "pool so late same-prefix requests still hit "
                        "after their cohort finished (needs "
                        "--share-prefix)")
    p.add_argument("--chunked-prefill", action="store_true",
                   help="advance prefills a chunk per tick, interleaved "
                        "with decode (fused scatter+attend paged-"
                        "prefill kernel; bounds the decode inter-token "
                        "gap under long prompts; paged mode only)")
    p.add_argument("--prefill-chunk", type=int, default=64,
                   help="prompt tokens per prefill chunk (multiple of "
                        "--page-size)")
    p.add_argument("--deadline-s", type=float, default=None,
                   help="per-request admission deadline in seconds")
    p.add_argument("--max-seq", type=int, default=128)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--attn-chunk", type=int, default=1024)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json-out", default=None)
    return p.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
