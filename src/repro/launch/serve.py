"""Serving launcher: quantize a model with PTQ1.61, run the continuous-
batching engine over a stream of requests (deliverable b, serving flavor).

    PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm --requests 8

Weights are quantized data-free (fast path) or with the full calibrated
pipeline (--calibrated).  ``--kernel`` dispatches the fused Pallas
mixed_matmul (interpret mode on CPU) instead of the XLA dequant path.
``--paged`` serves from the paged KV cache (block-table allocator +
FCFS/preemption scheduler; see repro.runtime.paged_cache) with
``--page-size`` tokens per page and a ``--pool-pages`` global budget;
paged decode attention runs through the Pallas flash-decode kernel on
feasible shapes (``--no-paged-kernel`` pins the XLA dense-gather
reference path).  Engine metrics (tokens/s, TTFT, queue depth, page
utilization) are included in the JSON output either way.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.bits import model_bits
from repro.core.pipeline import (quantize_model_ptq161,
                                 quantize_params_data_free)
from repro.core.qlinear import QuantConfig
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import model as M
from repro.models.common import Parallel
from repro.runtime.engine import Engine

Tree = Any


def run(args):
    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    par = Parallel(remat=False, attn_chunk=args.attn_chunk)
    params = M.init_params(cfg, par, jax.random.PRNGKey(args.seed))

    qcfg = QuantConfig(ratio=args.ratio, multiple=args.multiple,
                       steps=args.opt_steps, use_kernel=args.kernel)
    corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab, seed=args.seed))

    t0 = time.time()
    if args.quantize == "none":
        qparams = params
    elif args.quantize == "calibrated":
        if args.fused:
            print("[warn] --fused ignored for calibrated quantization "
                  "(per-projection QLinears cannot be fused post-hoc)")
        calib = [{"tokens": jnp.asarray(t)} for t, _ in
                 corpus.batches(1, args.calib_seq, args.calib_segments,
                                split="calib")]
        qparams = quantize_model_ptq161(cfg, par, params, calib, qcfg,
                                        min_dim=args.min_dim)
    else:  # data-free
        qparams = quantize_params_data_free(params, qcfg,
                                            min_dim=args.min_dim,
                                            fuse=args.fused)
    t_quant = time.time() - t0

    if args.quantize != "none":
        rep = model_bits(qparams)
        print(f"[quant] {args.quantize} in {t_quant:.1f}s — "
              f"{rep['avg_bits_per_quantized_weight']:.3f} bits/weight over "
              f"{rep['quantized_weights']:,} weights")

    engine = Engine(cfg, par, qparams, n_slots=args.slots,
                    max_seq=args.max_seq,
                    prefill_buckets=(args.max_seq // 8, args.max_seq // 2),
                    paged=args.paged, page_size=args.page_size,
                    pool_pages=args.pool_pages,
                    paged_kernel=not args.no_paged_kernel,
                    fuse_projections=args.fused and args.quantize == "none")

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, args.max_seq // 4))
        prompt = corpus.document(10_000 + i, plen)
        reqs.append(engine.submit(prompt, max_new=args.max_new,
                                  temperature=args.temperature,
                                  deadline_s=args.deadline_s))

    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    out = {
        "requests": len(reqs),
        "generated_tokens": toks,
        "wall_s": dt,
        "tokens_per_s": toks / max(dt, 1e-9),
        "all_done": all(r.done for r in reqs),
        "quantize_mode": args.quantize,
        "quantize_s": t_quant,
        "cache_backend": engine.backend.name,
        "engine_metrics": engine.metrics.snapshot(),
    }
    print(json.dumps(out, indent=2))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
    return out


def parse_args(argv=None):
    p = argparse.ArgumentParser(description="repro serving launcher")
    p.add_argument("--arch", default="tiny-lm")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--quantize", default="datafree",
                   choices=["none", "datafree", "calibrated"])
    p.add_argument("--kernel", action="store_true",
                   help="use the fused Pallas mixed_matmul path")
    p.add_argument("--fused", action="store_true",
                   help="N-fuse QKV / gate+up projections (decode fast "
                        "path): fused packed layouts for data-free "
                        "quantization, fp concat fusion for --quantize none")
    p.add_argument("--ratio", type=float, default=0.2)
    p.add_argument("--multiple", type=int, default=16)
    p.add_argument("--min-dim", type=int, default=32)
    p.add_argument("--opt-steps", type=int, default=3)
    p.add_argument("--calib-segments", type=int, default=4)
    p.add_argument("--calib-seq", type=int, default=64)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--paged", action="store_true",
                   help="paged KV cache (block tables + shared page pool)")
    p.add_argument("--page-size", type=int, default=16,
                   help="tokens per KV page (paged mode)")
    p.add_argument("--pool-pages", type=int, default=None,
                   help="total pages in the pool (default: full parity "
                        "with the contiguous layout, slots*max_seq/page)")
    p.add_argument("--no-paged-kernel", action="store_true",
                   help="pin paged decode attention to the XLA-gather "
                        "reference path instead of the Pallas "
                        "flash-decode kernel")
    p.add_argument("--deadline-s", type=float, default=None,
                   help="per-request admission deadline in seconds")
    p.add_argument("--max-seq", type=int, default=128)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--attn-chunk", type=int, default=1024)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json-out", default=None)
    return p.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
