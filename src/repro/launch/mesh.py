"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — smoke tests see 1 CPU device,
the dry-run sees the 512 forced host devices it sets up before import.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is an
outer data-parallel ring — cross-pod traffic is gradient all-reduce only
(DCN-friendly), while TP ("model") stays inside a pod's ICI domain.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across versions: 0.4.x has no ``axis_types`` kwarg
    (Auto is the only behavior); newer jax wants it spelled explicitly."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for smoke paths that still want `with mesh:`."""
    return compat_make_mesh((1, 1), ("data", "model"))


def mesh_devices(mesh) -> int:
    return int(mesh.devices.size)
