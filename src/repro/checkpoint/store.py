"""Atomic, manifest-versioned checkpointing (fault-tolerance substrate).

Layout:
    <dir>/step_<N>/           one .npy per leaf + manifest.msgpack
    <dir>/LATEST              text file: highest durable step

Guarantees:
  * **atomic**: leaves write into `step_<N>.tmp`, fsync'd, then a single
    `os.rename` publishes the step — a crash mid-save never corrupts the
    restore path (rename is atomic on POSIX);
  * **template-keyed**: leaves are stored by tree-path string and restored
    *into* a template tree (abstract or concrete), so checkpoints survive
    code-level tree reordering and restore onto ANY mesh — arrays are
    saved unsharded per leaf, and the loader re-shards via the template's
    shardings (this is what makes elastic re-mesh restarts work);
  * quantized params (QLinear pytrees) round-trip transparently — they
    flatten to ordinary array leaves.

On a real multi-host fleet each host would save its addressable shards
(process-local npy + shared manifest); the single-process container keeps
the same interface.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import msgpack
import numpy as np

Tree = Any

# numpy can't natively serialize bf16 etc. — store the raw bits with the
# logical dtype recorded in the manifest
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8}


def _to_numpy(leaf) -> Tuple[np.ndarray, str]:
    arr = np.asarray(jax.device_get(leaf))
    name = jnp.asarray(leaf).dtype.name if hasattr(leaf, "dtype") else arr.dtype.name
    if name in _BITCAST:
        return arr.view(_BITCAST[name]), name
    return arr, name


def _from_numpy(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _BITCAST:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _leafname(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save_checkpoint(ckpt_dir: str, step: int, tree: Tree,
                    extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = jax.tree_util.tree_leaves_with_path(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr, dtype_name = _to_numpy(leaf)
        np.save(os.path.join(tmp, _leafname(i)), arr)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "file": _leafname(i),
            "shape": list(arr.shape),
            "dtype": dtype_name,
        })
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore_checkpoint(ckpt_dir: str, template: Tree,
                       step: Optional[int] = None,
                       shardings: Optional[Tree] = None
                       ) -> Tuple[Tree, int]:
    """Restore into `template`'s structure.  With `shardings` (a matching
    NamedSharding tree) leaves are placed sharded — elastic re-mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())

    by_path = {e["path"]: e for e in manifest["leaves"]}
    tpl_leaves = jax.tree_util.tree_leaves_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(tpl_leaves))
    out = []
    for (path, tpl), shd in zip(tpl_leaves, shard_leaves):
        key = jax.tree_util.keystr(path)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(d, by_path[key]["file"]))
        arr = _from_numpy(arr, by_path[key]["dtype"])
        expect = tuple(getattr(tpl, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {expect}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr))
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, out), step
