"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Semantics contract (shared with the kernels):
  * packed layouts are those of ``repro.core.pack`` (8 signs / 2 nibbles
    per byte along K, N contiguous);
  * binary  : y = ((x · α_r2) @ sign) · (α_s · α_r1)         [Eq. 9]
  * int4    : y = x @ ((q − z)·s)          (per-input-channel s, z)
  * mixed   : y = int4(x[:, :k_s]) + binary(x[:, k_s:])      [PTQ1.61 linear]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pack


def binary_matmul_ref(x: jax.Array, bits: jax.Array, alpha_out: jax.Array,
                      alpha_in: jax.Array) -> jax.Array:
    """x (M,K) f32/bf16; bits (K//8,N) u8; alpha_out (N,); alpha_in (K,)."""
    sign = pack.unpack_bits(bits, axis=-2, dtype=jnp.float32)
    y = (x.astype(jnp.float32) * alpha_in[None, :]) @ sign
    return (y * alpha_out[None, :]).astype(x.dtype)


def int4_matmul_ref(x: jax.Array, w4: jax.Array, s4: jax.Array,
                    z4: jax.Array) -> jax.Array:
    """x (M,K); w4 (K//2,N) u8 nibbles; s4,z4 (K,) per input channel."""
    q = pack.unpack_nibbles(w4, axis=-2, dtype=jnp.float32)
    w = (q - z4[:, None]) * s4[:, None]
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def mixed_matmul_ref(x: jax.Array, w4: jax.Array, s4: jax.Array,
                     z4: jax.Array, bits: jax.Array, alpha_out: jax.Array,
                     alpha_in: jax.Array) -> jax.Array:
    """x (M,K) ALREADY salient-first permuted; k_s = 2*w4.shape[0]."""
    k_s = w4.shape[-2] * 2
    y4 = int4_matmul_ref(x[:, :k_s], w4, s4, z4)
    yb = binary_matmul_ref(x[:, k_s:], bits, alpha_out, alpha_in)
    return y4 + yb
