"""Pallas TPU kernel: the fused PTQ1.61 linear.

One pallas_call computes  y = x_s @ W4deq + ((x_b·α_r2) @ sign)·(α_s·α_r1)
over a salient-first-permuted input x (the structured mask as a contiguous
channel split — DESIGN.md §3).  The K grid covers k_s/bk int4 steps then
k_b/bk binary steps; `pl.when` selects the unpack path, so each step
streams only its own packed bytes (no second kernel launch, no (M,N)
re-read between the two halves — that is the fusion win over calling
int4_matmul + binary_matmul).

Requires a K block that divides BOTH k_s and k_b (QuantConfig.multiple
guarantees one at production shapes); block sizes default to the
:mod:`repro.kernels.autotune` cost model and a requested ``bk`` that
only divides one span is repaired to the largest common divisor rather
than asserting.  ops.mixed_matmul falls back to the XLA path before
calling in when no feasible tiling exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune
from repro.kernels.binary_matmul import _unpack_bits_block
from repro.kernels.int4_matmul import _unpack_nibbles_block


def _kernel(x_ref, w4_ref, s_ref, z_ref, bits_ref, a_in_ref, a_out_ref,
            o_ref, *, bk, bn, k4_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k < k4_steps)
    def _int4():
        q = _unpack_nibbles_block(w4_ref[...], bk, bn)
        w = (q - z_ref[...][:, None]) * s_ref[...][:, None]
        o_ref[...] += jax.lax.dot(x_ref[...].astype(jnp.bfloat16),
                                  w.astype(jnp.bfloat16),
                                  preferred_element_type=jnp.float32)

    @pl.when(k >= k4_steps)
    def _binary():
        x = x_ref[...].astype(jnp.float32) * a_in_ref[...][None, :]
        sign = _unpack_bits_block(bits_ref[...], bk, bn)
        acc = jax.lax.dot(x.astype(jnp.bfloat16), sign,
                          preferred_element_type=jnp.float32)
        o_ref[...] += acc * a_out_ref[...][None, :]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def mixed_matmul(x: jax.Array, w4: jax.Array, s4: jax.Array, z4: jax.Array,
                 bits: jax.Array, alpha_out: jax.Array, alpha_in: jax.Array,
                 *, bm: int = None, bn: int = None, bk: int = None,
                 interpret: bool = True) -> jax.Array:
    """x (M,K) permuted salient-first; returns (M,N) in x.dtype.

    ``bm``/``bn``/``bk`` default to the autotuner's pick for this
    (M, k_s, k_b, N).  An explicit ``bk`` acts as a cap: the kernel uses
    the largest common divisor of (k_s, k_b) at or below it — a bk that
    divides only one span (e.g. k_s=128, k_b=192 with bk=128) is
    repaired to 64 instead of tripping an assert mid-trace.
    """
    m, kdim = x.shape
    n = bits.shape[1]
    k_s = w4.shape[0] * 2
    k_b = bits.shape[0] * 8
    if k_s + k_b != kdim:
        raise ValueError(f"k_s+k_b={k_s}+{k_b} != x K {kdim}")
    bm, bn, bk = autotune.resolve_blocks(m, k_s, k_b, n, bm, bn, bk,
                                         bk_default=128)
    if bk is None or m % bm or n % bn or bk % 8:
        raise ValueError(
            f"infeasible mixed blocks (bm,bn,bk)=({bm},{bn},{bk}) for "
            f"(M,k_s,k_b,N)=({m},{k_s},{k_b},{n}); route through "
            f"repro.kernels.ops.mixed_matmul for the XLA fallback")
    k4_steps = k_s // bk
    kb_steps = k_b // bk
    grid = (m // bm, n // bn, k4_steps + kb_steps)

    # index maps: clamp into each operand's own K range
    def x_map(i, j, k):
        return (i, k)

    def w4_map(i, j, k):
        return (jnp.minimum(k, max(k4_steps - 1, 0)), j)

    def sz_map(i, j, k):
        return (jnp.minimum(k, max(k4_steps - 1, 0)),)

    def bits_map(i, j, k):
        return (jnp.clip(k - k4_steps, 0, max(kb_steps - 1, 0)), j)

    def ain_map(i, j, k):
        return (jnp.clip(k - k4_steps, 0, max(kb_steps - 1, 0)),)

    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, bn=bn, k4_steps=k4_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((bk // 2, bn), w4_map),
            pl.BlockSpec((bk,), sz_map),
            pl.BlockSpec((bk,), sz_map),
            pl.BlockSpec((bk // 8, bn), bits_map),
            pl.BlockSpec((bk,), ain_map),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w4, s4.astype(jnp.float32), z4.astype(jnp.float32), bits,
      alpha_in.astype(jnp.float32), alpha_out.astype(jnp.float32))
    return out.astype(x.dtype)
