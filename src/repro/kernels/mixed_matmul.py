"""Pallas TPU kernel: the fused PTQ1.61 linear.

One pallas_call computes  y = x_s @ W4deq + ((x_b·α_r2) @ sign)·(α_s·α_r1)
over a salient-first-permuted input x (the structured mask as a contiguous
channel split — DESIGN.md §3).  The K grid covers k_s/bk int4 steps then
k_b/bk binary steps; `pl.when` selects the unpack path, so each step
streams only its own packed bytes (no second kernel launch, no (M,N)
re-read between the two halves — that is the fusion win over calling
int4_matmul + binary_matmul).

The salient-first permutation itself can run INSIDE the kernel: pass
``perm`` and it rides in as a scalar-prefetch operand, the activation
block spec widens to the full (bm, K) row (fetched once per M tile), and
each K step gathers its own ``perm[k·bk:(k+1)·bk]`` columns in VMEM —
no host-side gather materializes a permuted copy of x in HBM.
``ops.mixed_matmul`` enables this whenever the full-K tile fits the
VMEM budget (``autotune.gather_in_kernel_ok``), which always holds at
decode M.

Requires a K block that divides BOTH k_s and k_b (QuantConfig.multiple
guarantees one at production shapes); block sizes default to the
:mod:`repro.kernels.autotune` cost model and a requested ``bk`` that
only divides one span is repaired to the largest common divisor rather
than asserting.  ops.mixed_matmul falls back to the XLA path before
calling in when no feasible tiling exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune
from repro.kernels.binary_matmul import _unpack_bits_block
from repro.kernels.int4_matmul import _unpack_nibbles_block


def _body(x_tile, w4_ref, s_ref, z_ref, bits_ref, a_in_ref, a_out_ref,
          o_ref, *, k, bk, bn, k4_steps):
    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k < k4_steps)
    def _int4():
        q = _unpack_nibbles_block(w4_ref[...], bk, bn)
        w = (q - z_ref[...][:, None]) * s_ref[...][:, None]
        o_ref[...] += jax.lax.dot(x_tile.astype(jnp.bfloat16),
                                  w.astype(jnp.bfloat16),
                                  preferred_element_type=jnp.float32)

    @pl.when(k >= k4_steps)
    def _binary():
        x = x_tile.astype(jnp.float32) * a_in_ref[...][None, :]
        sign = _unpack_bits_block(bits_ref[...], bk, bn)
        acc = jax.lax.dot(x.astype(jnp.bfloat16), sign,
                          preferred_element_type=jnp.float32)
        o_ref[...] += acc * a_out_ref[...][None, :]


def _kernel(x_ref, w4_ref, s_ref, z_ref, bits_ref, a_in_ref, a_out_ref,
            o_ref, *, bk, bn, k4_steps):
    _body(x_ref[...], w4_ref, s_ref, z_ref, bits_ref, a_in_ref, a_out_ref,
          o_ref, k=pl.program_id(2), bk=bk, bn=bn, k4_steps=k4_steps)


def _kernel_gather(perm_ref, x_ref, w4_ref, s_ref, z_ref, bits_ref,
                   a_in_ref, a_out_ref, o_ref, *, bk, bn, k4_steps):
    """Gather-in-kernel variant: x_ref holds the UNpermuted (bm, K) row
    block; this step's salient-first columns are selected in VMEM from
    the scalar-prefetched perm."""
    k = pl.program_id(2)
    idx = perm_ref[pl.ds(k * bk, bk)]
    _body(jnp.take(x_ref[...], idx, axis=1), w4_ref, s_ref, z_ref,
          bits_ref, a_in_ref, a_out_ref, o_ref, k=k, bk=bk, bn=bn,
          k4_steps=k4_steps)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def mixed_matmul(x: jax.Array, w4: jax.Array, s4: jax.Array, z4: jax.Array,
                 bits: jax.Array, alpha_out: jax.Array, alpha_in: jax.Array,
                 perm: jax.Array = None, *, bm: int = None, bn: int = None,
                 bk: int = None, interpret: bool = True) -> jax.Array:
    """x (M,K) permuted salient-first; returns (M,N) in x.dtype.

    With ``perm`` given, x is taken in ORIGINAL channel order and the
    permutation happens inside the kernel (scalar-prefetched indices,
    full-K x tile) — bit-identical to pre-gathering, since the gather is
    pure data movement.

    ``bm``/``bn``/``bk`` default to the autotuner's pick for this
    (M, k_s, k_b, N).  An explicit ``bk`` acts as a cap: the kernel uses
    the largest common divisor of (k_s, k_b) at or below it — a bk that
    divides only one span (e.g. k_s=128, k_b=192 with bk=128) is
    repaired to 64 instead of tripping an assert mid-trace.
    """
    m, kdim = x.shape
    n = bits.shape[1]
    k_s = w4.shape[0] * 2
    k_b = bits.shape[0] * 8
    if k_s + k_b != kdim:
        raise ValueError(f"k_s+k_b={k_s}+{k_b} != x K {kdim}")
    bm, bn, bk = autotune.resolve_blocks(m, k_s, k_b, n, bm, bn, bk,
                                         bk_default=128)
    if bk is None or m % bm or n % bn or bk % 8:
        raise ValueError(
            f"infeasible mixed blocks (bm,bn,bk)=({bm},{bn},{bk}) for "
            f"(M,k_s,k_b,N)=({m},{k_s},{k_b},{n}); route through "
            f"repro.kernels.ops.mixed_matmul for the XLA fallback")
    k4_steps = k_s // bk
    kb_steps = k_b // bk
    grid = (m // bm, n // bn, k4_steps + kb_steps)

    # index maps: clamp into each operand's own K range
    def w4_map(i, j, k):
        return (jnp.minimum(k, max(k4_steps - 1, 0)), j)

    def sz_map(i, j, k):
        return (jnp.minimum(k, max(k4_steps - 1, 0)),)

    def bits_map(i, j, k):
        return (jnp.clip(k - k4_steps, 0, max(kb_steps - 1, 0)), j)

    def ain_map(i, j, k):
        return (jnp.clip(k - k4_steps, 0, max(kb_steps - 1, 0)),)

    operands = (x, w4, s4.astype(jnp.float32), z4.astype(jnp.float32), bits,
                alpha_in.astype(jnp.float32), alpha_out.astype(jnp.float32))
    kern = functools.partial(
        _kernel if perm is None else _kernel_gather,
        bk=bk, bn=bn, k4_steps=k4_steps)
    out_spec_args = dict(
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret)
    if perm is None:
        in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))]
        tail = lambda f: f                      # 3-arg index maps as-is
        out_map = lambda i, j, k: (i, j)
    else:
        # scalar-prefetch mode: every index map gains a trailing perm
        # ref arg; x widens to the full-K row block, fetched once per i
        in_specs = [pl.BlockSpec((bm, kdim), lambda i, j, k, p: (i, 0))]
        tail = lambda f: (lambda i, j, k, p: f(i, j, k))
        out_map = lambda i, j, k, p: (i, j)
    in_specs += [
        pl.BlockSpec((bk // 2, bn), tail(w4_map)),
        pl.BlockSpec((bk,), tail(sz_map)),
        pl.BlockSpec((bk,), tail(sz_map)),
        pl.BlockSpec((bk // 8, bn), tail(bits_map)),
        pl.BlockSpec((bk,), tail(ain_map)),
        pl.BlockSpec((bn,), tail(lambda i, j, k: (j,))),
    ]
    if perm is None:
        out = pl.pallas_call(
            kern, grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), out_map), **out_spec_args,
        )(*operands)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), out_map))
        out = pl.pallas_call(kern, grid_spec=grid_spec, **out_spec_args,
                             )(perm.astype(jnp.int32), *operands)
    return out.astype(x.dtype)
