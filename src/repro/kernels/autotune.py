"""Block-size autotuner for the packed-weight Pallas kernels.

The kernels' original hard-coded defaults (bm=256, bn=512, bk=128/256)
were tuned for calibration-shaped GEMMs (M≈256).  Decode runs the same
kernels at M = n_slots (1–16): a 256-row M block is meaningless there,
and a 512-column N block forces ``N/512`` re-reads of the (M, K)
activation tile that at decode shapes could sit in VMEM whole.  This
module replaces the constants with a small static cost model:

* **feasibility** — every block dim must divide its array dim (the
  kernels have no remainder handling), ``bn`` must keep the 128-lane
  alignment, and ``bk`` must be a *common* divisor of the int4 and
  binary K spans (a multiple of 8 so packed bytes split evenly);
* **VMEM budget** — double-buffered input tiles plus the f32
  accumulator must fit ``vmem_budget`` (default 8 MiB of the ~16 MiB
  v5e VMEM, leaving room for Pallas' own pipelining);
* **HBM bytes per call** — weight bytes stream once per M tile,
  activation bytes once per N tile, so the model prefers the largest
  feasible ``bm``/``bn`` (for decode M this collapses to ``bm=M`` and,
  VMEM permitting, ``bn=N`` — one x read per call);
* **modeled time** — ``max(flops/PEAK_FLOPS, bytes/HBM_BW)`` with the
  v5e constants from ``repro.launch.hlo_analysis`` (the same numbers
  the roofline report uses).

``choose_blocks`` is memoized (the dispatch cache): one search per
distinct ``(M, k_s, k_b, N)``, O(1) afterwards — decode calls the same
handful of shapes millions of times.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS

# Input tiles are double-buffered by the Pallas pipeline; keep their two
# copies plus the resident f32 accumulator inside half of VMEM.
VMEM_BUDGET = 8 * 1024 * 1024
BM_CAP = 256          # MXU saturates at 128 rows; 256 amortizes setup
BK_CAP = 512
BN_CAP = 32768


@dataclass(frozen=True)
class BlockChoice:
    """One (bm, bn, bk) pick plus the cost-model terms behind it."""
    bm: int
    bn: int
    bk: int
    vmem_bytes: int
    hbm_bytes: int
    time_s: float


def _divisors(n: int, cap: int) -> Tuple[int, ...]:
    """Divisors of ``n`` that are ≤ cap, descending."""
    if n <= 0:
        return ()
    out = [d for d in range(1, int(math.isqrt(n)) + 1) if n % d == 0]
    out += [n // d for d in reversed(out) if n // d not in out]
    return tuple(sorted((d for d in out if d <= cap), reverse=True))


def common_bk(k_s: int, k_b: int, cap: Optional[int] = None,
              align: int = 8) -> Optional[int]:
    """Largest multiple-of-``align`` K block that divides BOTH the int4
    span ``k_s`` and the binary span ``k_b`` (an empty span constrains
    nothing).  Returns None when no such block exists — the caller must
    fall back to the XLA path rather than assert inside the kernel."""
    if cap is None:
        cap = BK_CAP
    g = math.gcd(max(k_s, 0), max(k_b, 0))
    if g == 0:
        return None
    for d in _divisors(g, cap):
        if d % align == 0:
            return d
    return None


def resolve_blocks(m: int, k_s: int, k_b: int, n: int,
                   bm: Optional[int], bn: Optional[int], bk: Optional[int],
                   *, align: int = 8,
                   bk_default: int = 256) -> Tuple[int, int, Optional[int]]:
    """Shared block-dim resolution for all three packed kernels.

    Missing dims come from the autotuner (legacy MXU constants when no
    feasible choice exists); explicit dims are clamped to the array and
    a ``bk`` that fails to divide a K span is repaired to the largest
    common divisor at or below it (multiple of ``align``).  Returns
    ``bk=None`` when no feasible K block exists — callers raise their
    kernel-specific error.
    """
    choice = choose_blocks(m, k_s, k_b, n)
    if bm is None:
        bm = choice.bm if choice else min(BM_CAP, m)
    if bn is None:
        bn = choice.bn if choice else min(512, n)
    if bk is None:
        bk = choice.bk if choice else bk_default
    bm, bn = min(bm, m), min(bn, n)
    bk = min((bk,) + tuple(s for s in (k_s, k_b) if s))
    if any(s % bk for s in (k_s, k_b) if s):
        bk = common_bk(k_s, k_b, cap=bk, align=align)
    return bm, bn, bk


def kernel_vmem_bytes(bm: int, bn: int, bk: int) -> int:
    """Per-step VMEM footprint of the mixed kernel: double-buffered
    input tiles (x bf16, packed nibbles + bits, f32 scale vectors) plus
    the revisited f32 accumulator tile."""
    inputs = (bm * bk * 2            # x tile, bf16
              + (bk // 2) * bn       # w4 tile, u8
              + (bk // 8) * bn       # bits tile, u8
              + 3 * bk * 4           # s4 / z4 / alpha_in slices
              + bn * 4)              # alpha_out slice
    return 2 * inputs + bm * bn * 4


def gather_in_kernel_ok(choice: BlockChoice, m: int, k: int,
                        vmem_budget: Optional[int] = None) -> bool:
    """Whether the mixed kernel can host the salient-channel gather
    itself: the activation tile grows from (bm, bk) to (bm, K) — the
    full permuted row must sit in VMEM so scalar-prefetched perm indices
    can select each K step's columns.  In exchange the activation is
    fetched once per M tile instead of once per (M, N) tile and the
    host-side XLA gather disappears.  True when the swap still fits the
    VMEM budget."""
    if vmem_budget is None:
        vmem_budget = VMEM_BUDGET
    bm = min(choice.bm, m)
    grown = choice.vmem_bytes - 2 * bm * choice.bk * 2 + 2 * bm * k * 2
    return grown <= vmem_budget


def weight_bytes(k_s: int, k_b: int, n: int) -> int:
    """Packed weight bytes one call must stream (nibbles + sign bits)."""
    return (k_s // 2) * n + (k_b // 8) * n


def vector_bytes(k_s: int, k_b: int, n: int) -> int:
    """f32 side-band vectors: s4+z4 (k_s each), alpha_in (k_b),
    alpha_out (n)."""
    return (2 * k_s + k_b + n) * 4


def modeled_hbm_bytes(m: int, k_s: int, k_b: int, n: int,
                      bm: int, bn: int) -> int:
    """HBM bytes per kernel call under the chosen tiling: each weight
    byte streams once per M tile, the bf16 activation once per N tile,
    vectors once, and the output writes once (f32 accumulator)."""
    k = k_s + k_b
    return (weight_bytes(k_s, k_b, n) * _cdiv(m, bm)
            + m * k * 2 * _cdiv(n, bn)
            + vector_bytes(k_s, k_b, n)
            + m * n * 4)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def modeled_time_s(m: int, k: int, n: int, hbm_bytes: int) -> float:
    return max(2.0 * m * k * n / PEAK_FLOPS, hbm_bytes / HBM_BW)


def choose_blocks(m: int, k_s: int, k_b: int, n: int,
                  vmem_budget: Optional[int] = None) -> Optional[BlockChoice]:
    """Pick (bm, bn, bk) for one mixed/int4/binary matmul call.

    Pass ``k_s=0`` for a pure-binary layout or ``k_b=0`` for pure int4.
    Returns None when no feasible tiling exists (misaligned N, no common
    K block, or a degenerate shape) — callers fall back to XLA.

    The memoization IS the dispatch cache: serving decodes hit the same
    few (M, k_s, k_b, N) keys every step.  The module-level knobs
    (``VMEM_BUDGET``, ``BM_CAP``/``BK_CAP``/``BN_CAP``) are read here at
    call time and are part of the cache key, so reassigning them takes
    effect immediately — including for already-seen shapes.
    """
    return _choose_blocks_cached(
        m, k_s, k_b, n,
        VMEM_BUDGET if vmem_budget is None else vmem_budget,
        BM_CAP, BK_CAP, BN_CAP)


@functools.lru_cache(maxsize=4096)
def _choose_blocks_cached(m: int, k_s: int, k_b: int, n: int,
                          vmem_budget: int, bm_cap: int, bk_cap: int,
                          bn_cap: int) -> Optional[BlockChoice]:
    if m <= 0 or n <= 0 or k_s + k_b <= 0:
        return None
    if n % 128 != 0:
        return None
    bk0 = common_bk(k_s, k_b, cap=bk_cap)
    if bk0 is None:
        return None
    k = k_s + k_b
    bks = tuple(d for d in _divisors(bk0, bk_cap) if d % 8 == 0)
    bns = tuple(d for d in _divisors(n, bn_cap) if d % 128 == 0)
    bms = _divisors(m, bm_cap) or (m,)
    best: Optional[BlockChoice] = None
    for bm in bms:
        for bn in bns:
            # feasibility of this (bm, bn) is monotone in bk: take the
            # largest bk that fits, larger bk = fewer grid steps
            for bk in bks:
                vmem = kernel_vmem_bytes(bm, bn, bk)
                if vmem > vmem_budget:
                    continue
                hbm = modeled_hbm_bytes(m, k_s, k_b, n, bm, bn)
                cand = BlockChoice(bm, bn, bk, vmem, hbm,
                                   modeled_time_s(m, k, n, hbm))
                if (best is None or cand.hbm_bytes < best.hbm_bytes
                        or (cand.hbm_bytes == best.hbm_bytes
                            and cand.bk > best.bk)):
                    best = cand
                break
    return best


# ---------------------------------------------------------------------------
# Paged-attention decode kernel (KV page tiles)
# ---------------------------------------------------------------------------
# The paged flash-decode kernel's KV tile is one pool page per grid step:
# (ps, bh, dh) slabs of K and V for `bh` kv heads at a time.  The only
# free block dim is `bh` — pages are non-contiguous in the pool, so the
# tile cannot span pages, and ps/dh are fixed by the pool layout.  The
# model picks the largest `bh` whose double-buffered K/V tiles + the q
# tile + the f32 (m, l, acc) scratch fit the VMEM budget (fewer grid
# steps, better DMA overlap), and exposes the per-token KV read bytes
# the serving bench asserts against.


@dataclass(frozen=True)
class PagedAttnChoice:
    """KV-tile pick for one paged-attention call plus its cost terms."""
    bh: int                    # kv heads per block
    vmem_bytes: int
    kv_bytes_per_token: int    # K+V bytes one live token costs per read


def paged_kv_bytes_per_token(hkv: int, dh: int, itemsize: int = 2) -> int:
    """K+V bytes the decode read streams per live token (all kv heads)."""
    return 2 * hkv * dh * itemsize


def paged_read_bytes(context_len: int, ps: int, hkv: int, dh: int,
                     itemsize: int = 2) -> int:
    """Modeled KV bytes ONE decode step reads for a request of
    ``context_len`` live tokens under the paged kernel: whole pages, so
    at most one page of slack past the live tokens."""
    pages = -(-max(int(context_len), 0) // ps)
    return pages * ps * paged_kv_bytes_per_token(hkv, dh, itemsize)


def paged_attn_vmem_bytes(bh: int, rep: int, dh: int, ps: int,
                          kv_itemsize: int = 2, q_itemsize: int = 2) -> int:
    """Per-step VMEM footprint: double-buffered K/V page tiles and q
    tile, the f32 output tile, and the resident (m, l, acc) scratch."""
    kv = 2 * ps * bh * dh * kv_itemsize          # one K + one V tile
    qo = bh * rep * dh * (q_itemsize + 4)        # q tile + f32 out tile
    scratch = bh * rep * (dh + 2) * 4            # acc + m + l
    return 2 * (kv + qo) + scratch


def choose_paged_blocks(hkv: int, rep: int, dh: int, ps: int,
                        vmem_budget: Optional[int] = None,
                        ) -> Optional[PagedAttnChoice]:
    """Pick the kv-heads-per-block tile for a paged-attention shape, or
    None when even bh=1 cannot fit (callers fall back to the XLA gather
    path).  Memoized like :func:`choose_blocks` — decode hits the same
    (hkv, rep, dh, ps) key every layer of every tick."""
    return _choose_paged_cached(
        hkv, rep, dh, ps,
        VMEM_BUDGET if vmem_budget is None else vmem_budget)


@functools.lru_cache(maxsize=1024)
def _choose_paged_cached(hkv: int, rep: int, dh: int, ps: int,
                         vmem_budget: int) -> Optional[PagedAttnChoice]:
    if hkv <= 0 or rep <= 0 or dh <= 0 or ps <= 0:
        return None
    for bh in _divisors(hkv, hkv):
        vmem = paged_attn_vmem_bytes(bh, rep, dh, ps)
        if vmem <= vmem_budget:
            return PagedAttnChoice(bh, vmem,
                                   paged_kv_bytes_per_token(hkv, dh))
    return None


# ---------------------------------------------------------------------------
# Paged-prefill kernel (chunked scatter+attend tiles)
# ---------------------------------------------------------------------------
# The chunked-prefill kernel keeps the whole chunk's queries and the
# online-softmax scratch resident while streaming one KV page per grid
# step, so its VMEM footprint scales with (bh, rep, C) instead of the
# decode kernel's (bh, rep).  `bh` is again the only free dim; the model
# picks the largest fitting one and exposes the per-chunk traffic terms
# the serving bench accounts against (mirroring paged_read_bytes).


def paged_prefill_vmem_bytes(bh: int, rep: int, dh: int, ps: int, c: int,
                             kv_itemsize: int = 2,
                             q_itemsize: int = 2) -> int:
    """Per-step VMEM footprint of the chunked-prefill kernel:
    double-buffered context K/V page tiles AND chunk K/V tiles, the
    resident q block, the f32 output tile, and the (m, l, acc)
    scratch."""
    kv = 4 * ps * bh * dh * kv_itemsize          # ctx K/V + chunk K/V tiles
    q = bh * rep * c * dh * q_itemsize
    out = bh * rep * c * dh * 4
    scratch = bh * rep * c * (dh + 2) * 4        # acc + m + l
    return 2 * kv + q + out + scratch


def paged_prefill_read_bytes(start: int, length: int, ps: int, hkv: int,
                             dh: int, itemsize: int = 2) -> int:
    """Modeled KV bytes ONE chunk call moves for a chunk at ``start``
    with ``length`` live tokens: context pages stream in once, chunk
    pages write once (whole pages, so at most one page of slack) — the
    prefill mirror of :func:`paged_read_bytes`."""
    ctx_pages = -(-max(int(start), 0) // ps)
    chunk_pages = -(-max(int(length), 0) // ps)
    return ((ctx_pages + chunk_pages) * ps
            * paged_kv_bytes_per_token(hkv, dh, itemsize))


@dataclass(frozen=True)
class PagedPrefillChoice:
    """KV-tile pick for one chunked-prefill call plus its cost terms."""
    bh: int                    # kv heads per block
    vmem_bytes: int
    kv_bytes_per_token: int


def choose_prefill_blocks(c: int, hkv: int, rep: int, dh: int, ps: int,
                          vmem_budget: Optional[int] = None,
                          ) -> Optional[PagedPrefillChoice]:
    """Pick the kv-heads-per-block tile for a chunked-prefill shape, or
    None when even bh=1 cannot fit (callers fall back to the XLA
    dense-gather path).  Memoized like the other choosers — every chunk
    of every prompt hits the same (C, hkv, rep, dh, ps) key."""
    return _choose_prefill_cached(
        c, hkv, rep, dh, ps,
        VMEM_BUDGET if vmem_budget is None else vmem_budget)


@functools.lru_cache(maxsize=1024)
def _choose_prefill_cached(c: int, hkv: int, rep: int, dh: int, ps: int,
                           vmem_budget: int) -> Optional[PagedPrefillChoice]:
    if c <= 0 or hkv <= 0 or rep <= 0 or dh <= 0 or ps <= 0 or c % ps:
        return None
    for bh in _divisors(hkv, hkv):
        vmem = paged_prefill_vmem_bytes(bh, rep, dh, ps, c)
        if vmem <= vmem_budget:
            return PagedPrefillChoice(bh, vmem,
                                      paged_kv_bytes_per_token(hkv, dh))
    return None


def cache_info():
    """Dispatch-cache stats for the memoized choosers (matmul block
    picks, paged-attention KV tiles, chunked-prefill tiles)."""
    return {"matmul": _choose_blocks_cached.cache_info(),
            "paged_attention": _choose_paged_cached.cache_info(),
            "paged_prefill": _choose_prefill_cached.cache_info()}


def cache_clear() -> None:
    _choose_blocks_cached.cache_clear()
    _choose_paged_cached.cache_clear()
    _choose_prefill_cached.cache_clear()
