"""Pallas TPU kernel: packed int4 × bf16 matmul, per-input-channel grid.

Serves the salient 20% channels of a PTQ1.61 layer (and any plain
int4-quantized linear).  Same tiling discipline as binary_matmul; nibbles
unpack to (q−z)·s inside VMEM.  Because s, z are per *input* channel the
dequant folds into the x side:  x @ ((q−z)·s) = (x·s) @ q − (x·s·z)·Σ... —
we keep the direct form (unpack→dequant→MXU) for clarity; the fused
variant is in mixed_matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune


def _unpack_nibbles_block(packed: jax.Array, bk: int, bn: int) -> jax.Array:
    """(bk//2, bn) u8 -> (bk, bn) f32 codes 0..15 (low nibble = even k)."""
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = p >> 4
    inter = jnp.stack([lo, hi], axis=1)              # (bk/2, 2, bn)
    return inter.reshape(bk, bn).astype(jnp.float32)


def _kernel(x_ref, w4_ref, s_ref, z_ref, o_ref, *, bk, bn):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = _unpack_nibbles_block(w4_ref[...], bk, bn)
    w = (q - z_ref[...][:, None]) * s_ref[...][:, None]
    o_ref[...] += jax.lax.dot(x_ref[...].astype(jnp.bfloat16),
                              w.astype(jnp.bfloat16),
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int4_matmul(x: jax.Array, w4: jax.Array, s4: jax.Array, z4: jax.Array,
                *, bm: int = None, bn: int = None, bk: int = None,
                interpret: bool = True) -> jax.Array:
    """Blocks default to the autotuner (see :mod:`repro.kernels.autotune`)."""
    m, kdim = x.shape
    n = w4.shape[1]
    if w4.shape[0] * 2 != kdim:
        raise ValueError(f"w4 K span {w4.shape[0] * 2} != x K {kdim}")
    bm, bn, bk = autotune.resolve_blocks(m, kdim, 0, n, bm, bn, bk,
                                         align=2)
    if bk is None or m % bm or n % bn or kdim % bk or bk % 2:
        raise ValueError(
            f"infeasible int4 blocks (bm,bn,bk)=({bm},{bn},{bk}) for "
            f"(M,K,N)=({m},{kdim},{n})")

    grid = (m // bm, n // bn, kdim // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w4, s4.astype(jnp.float32), z4.astype(jnp.float32))
    return out.astype(x.dtype)
