"""Pallas TPU kernel: paged flash-decode attention over the shared KV pool.

One pallas_call attends every decode slot's query against its own pages
of the position-aligned pool ``(P, ps, hkv, dh)`` WITHOUT materializing
the gathered ``(B, nblk*ps, hkv, dh)`` context in HBM — the win the
paged serving path needs once PTQ1.61 weights stop dominating decode
traffic (the KV cache does).

Mechanics (the scalar-prefetch contract):

* ``block_tables`` (flattened ``(B*nblk,)``) and ``context_lens``
  ``(B,)`` ride in as *scalar-prefetch* operands, so they are resident
  in SMEM before the grid starts and the K/V BlockSpec index maps can
  read them: grid step ``(b, hg, j)`` DMAs pool page
  ``block_tables[b, j]`` straight HBM→VMEM.  No XLA gather, no dense
  intermediate.
* The grid walks ``(B, hkv/bh, nblk)`` with the page dim innermost; a
  VMEM scratch triple ``(m, l, acc)`` carries the online-softmax state
  across a request's pages (flash-decode) and the normalized output is
  written once at the last page step.
* **Early exit / ragged lengths**: steps past a request's last live
  page (or before its sliding-window start) skip compute via
  ``pl.when`` AND clamp their index map into the live page range, so
  the Pallas pipeline re-addresses the previous block and issues no new
  DMA — per-token HBM traffic is proportional to the LIVE context, not
  to ``nblk*ps`` table capacity.  Unassigned / freed table entries
  (``-1``) are masked the same way (fetch clamped to page 0, compute
  skipped), matching the XLA reference's implied-position mask.
* GQA: queries are blocked ``(bh, rep, dh)`` per kv-head group and
  contracted against ``(ps, bh, dh)`` page tiles with a batched dot —
  the head-group broadcast never leaves VMEM.  ``bh`` (kv heads per
  block) comes from :func:`repro.kernels.autotune.choose_paged_blocks`.

Numerics mirror ``repro.models.layers._attend``: bf16 operands into the
MXU with f32 accumulation, f32 softmax (scores divided by sqrt(dh),
optional logit softcap), probabilities fed back at the V dtype.  Rows
with ``context_lens == 0`` (inactive slots) produce exact zeros rather
than the reference's uniform-softmax garbage — both are discarded by
the engine.

``repro.models.layers.attention_decode_paged`` dispatches here behind a
feasibility check (mirroring ``ops.mixed_matmul``) and keeps the XLA
gather as the fallback/reference path.

**Head-dim padding**: pools for archs whose ``dh`` is off the 128-lane
TPU tile are allocated at ``ops.padded_head_dim(dh)`` with zero-padded
tails, so the kernel serves them instead of punting to the dense
gather.  The wrapper zero-pads q into the pool tile (zero lanes add
nothing to q·k), keeps the softmax scale at 1/sqrt(dh_logical), and
slices the padded output columns off — exact by construction.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune

NEG_INF = -1e30


def kv_block_index(bi, j, bt_flat, lens, *, ps: int, nblk: int,
                   window: Optional[int]):
    """Pool page the K/V BlockSpec addresses at grid step ``(bi, ·, j)``.

    THE fetch contract, shared by the kernel's index map and the
    instrumentation below: steps past the last live page, before the
    sliding-window start, or on inactive rows clamp onto an
    already-fetched live page — the Pallas pipeline sees an unchanged
    block index and issues no new DMA."""
    length = lens[bi]
    last = jnp.maximum((length - 1) // ps, 0)
    if window is None:
        first = 0
    else:
        first = jnp.minimum(jnp.maximum(length - window, 0) // ps, last)
    jj = jnp.clip(j, first, last)
    return jnp.maximum(bt_flat[bi * nblk + jj], 0)


def fetched_page_counts(block_tables, context_lens, ps: int, *,
                        window: Optional[int] = None):
    """Replay the kernel's ACTUAL K/V index map over one decode step's
    grid and count the page DMAs it issues per request row (consecutive
    equal block indices re-address the resident tile — no fetch).

    This is measurement, not a cost model: it walks the same
    :func:`kv_block_index` the BlockSpec uses, so a regression in the
    clamp (e.g. dead steps fetching fresh pages again) shows up here —
    serving_bench asserts these counts stay within one page of each
    row's live context.  Returns an int array (B,)."""
    import numpy as np
    b, nblk = np.asarray(block_tables).shape
    counts = _fetched_page_counts_dev(
        jnp.asarray(np.asarray(block_tables).reshape(-1)),
        jnp.asarray(np.asarray(context_lens)), ps=ps, nblk=nblk,
        window=window)
    return np.asarray(counts)


@functools.partial(jax.jit, static_argnames=("ps", "nblk", "window"))
def _fetched_page_counts_dev(bt_flat, lens, *, ps, nblk, window):
    b = lens.shape[0]
    pages = jax.vmap(lambda bi: jax.vmap(
        lambda j: kv_block_index(bi, j, bt_flat, lens, ps=ps, nblk=nblk,
                                 window=window))(jnp.arange(nblk)))(
        jnp.arange(b))                                   # (B, nblk)
    changed = jnp.concatenate(
        [jnp.ones((b, 1), bool), pages[:, 1:] != pages[:, :-1]], axis=1)
    return jnp.sum(changed, axis=1)


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, ps, nblk, sm_scale, window, softcap):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page = bt_ref[b * nblk + j]
    length = len_ref[b]
    live = jnp.logical_and(page >= 0, j * ps < length)
    if window is not None:
        # skip pages wholly below the sliding-window start
        live = jnp.logical_and(live, (j + 1) * ps > length - window)

    @pl.when(live)
    def _page():
        q = q_ref[0]                       # (bh, rep, dh)
        k = k_ref[0]                       # (ps, bh, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(            # (bh, rep, ps)
            q, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        # sm_scale is 1/sqrt(dh_logical) — the LOGICAL head dim, not the
        # (possibly lane-padded) pool tile dim: padded lanes are zero in
        # q so they add nothing to the dot, but they must not inflate
        # the softmax temperature
        s = s.astype(jnp.float32) * sm_scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kp = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
        valid = kp < length
        if window is not None:
            valid = jnp.logical_and(valid, kp >= length - window)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nblk - 1)
    def _finalize():
        # inactive rows (length 0): l stays 0 -> exact zeros, never NaN
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "bh",
                                             "interpret"))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, context_lens: jax.Array, *,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    bh: Optional[int] = None,
                    interpret: bool = True) -> jax.Array:
    """Flash-decode over pool pages.

    q (B, hq, dh); k_pool/v_pool (P, ps, hkv, dh_pool); block_tables
    (B, nblk) int32 page ids (-1 = unassigned); context_lens (B,) int32
    live tokens per request (0 = inactive row -> zero output).  Returns
    (B, hq, dh) f32.  ``bh`` (kv heads per block) defaults to the
    autotuner's pick.

    ``dh_pool`` may exceed q's logical ``dh`` (lane-padded pools for
    archs with ``dh`` off the 128-lane TPU tile —
    ``ops.padded_head_dim``): q is zero-padded into the pool tile, the
    softmax scale stays 1/sqrt(dh_logical), and the padded output
    columns are sliced off — exact, since zero q lanes contribute
    nothing to q·k and the padded V columns never survive the slice.
    """
    b, hq, dh = q.shape
    num_pages, ps, hkv, dh_pool = k_pool.shape
    nblk = block_tables.shape[1]
    rep = hq // hkv
    if hq % hkv:
        raise ValueError(f"hq={hq} not a multiple of hkv={hkv}")
    if dh_pool < dh:
        raise ValueError(f"pool head dim {dh_pool} < query head dim {dh}")
    sm_scale = 1.0 / math.sqrt(dh)
    if dh_pool > dh:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, dh_pool - dh)))
    if bh is None:
        choice = autotune.choose_paged_blocks(hkv, rep, dh_pool, ps)
        if choice is None:
            raise ValueError(
                f"no feasible paged-attention blocks for (hkv, rep, dh, ps)"
                f"=({hkv}, {rep}, {dh_pool}, {ps}); route through "
                f"repro.models.layers.attention_decode_paged for the XLA "
                f"fallback")
        bh = choice.bh
    if hkv % bh:
        raise ValueError(f"bh={bh} must divide hkv={hkv}")
    qg = q.reshape(b, hkv, rep, dh_pool)
    grid = (b, hkv // bh, nblk)

    def q_map(bi, hg, j, bt, lens):
        return (bi, hg, 0, 0)

    def kv_map(bi, hg, j, bt, lens):
        # the shared fetch contract (see kv_block_index): dead steps
        # clamp onto an already-fetched live page -> no new DMA
        return (kv_block_index(bi, j, bt, lens, ps=ps, nblk=nblk,
                               window=window), 0, hg, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bh, rep, dh_pool), q_map),
            pl.BlockSpec((1, ps, bh, dh_pool), kv_map),
            pl.BlockSpec((1, ps, bh, dh_pool), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bh, rep, dh_pool), q_map),
        scratch_shapes=[
            pltpu.VMEM((bh, rep, 1), jnp.float32),       # running max
            pltpu.VMEM((bh, rep, 1), jnp.float32),       # running denom
            pltpu.VMEM((bh, rep, dh_pool), jnp.float32),  # weighted-V acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, ps=ps, nblk=nblk, sm_scale=sm_scale,
                          window=window, softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, dh_pool), jnp.float32),
        interpret=interpret,
    )(block_tables.reshape(-1).astype(jnp.int32),
      context_lens.astype(jnp.int32), qg, k_pool, v_pool)
    return out.reshape(b, hq, dh_pool)[..., :dh]
