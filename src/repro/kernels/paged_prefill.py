"""Pallas TPU kernel: fused scatter+attend chunked prefill over the KV pool.

One pallas_call advances ONE request's prefill by a chunk of ``C`` prompt
tokens: it writes the chunk's K/V straight into the request's pool pages
(the block table rides in as a scalar-prefetch operand, exactly like the
flash-decode kernel in ``paged_attention.py``) and computes causal flash
attention of the chunk's queries against all previously-written context
pages plus the in-chunk causal prefix — WITHOUT ever materializing the
dense ``(B, bucket, hkv, dh)`` prefill cache the whole-prompt path
splices from.  Per-chunk HBM traffic is ∝ (live context pages read +
chunk pages written), which is what lets a long prompt advance a bounded
slice per engine tick instead of stalling every in-flight decode.

Mechanics (the scalar-prefetch contract):

* ``bt_read`` is the request's full block-table row: grid step ``(hg, j)``
  with ``j < nblk`` DMAs context page ``bt_read[j]`` HBM→VMEM through the
  K/V BlockSpec index map.  Steps past the live context (``j*ps >=
  start``), before the sliding-window start, or on unassigned entries
  clamp onto an already-fetched page — no new DMA, mirroring
  ``paged_attention.kv_block_index``.
* ``bt_write`` is the request's *writable* row
  (:meth:`repro.runtime.paged_cache.BlockTables.writable_row`): shared
  (prefix-attached / COW) blocks are masked to ``-1`` and their writes
  are routed to the pool's **dump page** (the physical page at index
  ``num_pages`` that :func:`repro.models.layers.make_paged_cache`
  over-allocates) — the fused scatter needs a real write target where
  the XLA path uses ``mode="drop"``.
* The grid walks ``(hkv/bh, nblk + C/ps)``: the first ``nblk`` steps
  stream context pages through the online-softmax scratch
  ``(m, l, acc)``; the last ``C/ps`` steps attend the chunk's own K
  tiles (causal, straight from VMEM — in-chunk keys never round-trip
  through HBM) AND write each chunk page tile into the pool through the
  aliased K/V outputs.  GQA head groups, sliding window and logit
  softcap follow the decode kernel exactly.
* ``start`` must be page-aligned and ``C`` a page-size multiple, so
  every chunk page holds only chunk tokens; the final (ragged) chunk
  carries ``length < C`` and masks its dead tail both in attention and
  in the write index map (fully-dead pages go to the dump page).

Numerics: K/V arrive already cast to the pool dtype (so in-chunk
attention sees exactly the bytes later chunks will read back), scores
and softmax are f32, probabilities feed back at the V dtype —
bit-compatible with :func:`paged_prefill_xla`, the dense-gather
reference below that ``repro.models.layers.attention_prefill_paged``
falls back to on infeasible shapes.  The reference accumulates over the
SAME page-tile sequence with the same dot_general calls, so kernel and
fallback agree bit-exactly in f32 (the oracle property the tests pin).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune

NEG_INF = -1e30


def ctx_block_index(j, bt_read, start, *, ps: int, nblk: int,
                    window: Optional[int]):
    """Context pool page the K/V BlockSpec addresses at grid step
    ``(·, j)`` — the prefill twin of ``paged_attention.kv_block_index``:
    steps past the last context page (``j*ps >= start``), before the
    sliding-window start, or on dead entries clamp onto an
    already-fetched page so the pipeline issues no new DMA."""
    last = jnp.maximum(start // ps - 1, 0)
    if window is None:
        first = 0
    else:
        # oldest chunk query sits at position `start`: pages wholly
        # below start+1-window are invisible to every chunk query
        first = jnp.minimum(jnp.maximum(start + 1 - window, 0) // ps, last)
    jj = jnp.clip(j, first, last)
    return jnp.maximum(bt_read[jj], 0)


def _kernel(bt_r_ref, bt_w_ref, meta_ref, q_ref, kn_ref, vn_ref,
            kp_ref, vp_ref, o_ref, ko_ref, vo_ref, m_ref, l_ref, acc_ref,
            *, ps, nblk, ncp, c, sm_scale, window, softcap):
    j = pl.program_id(1)
    start = meta_ref[0]
    length = meta_ref[1]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    is_chunk = j >= nblk
    cp = jnp.maximum(j - nblk, 0)

    # ---- liveness ----------------------------------------------------
    ctx_live = jnp.logical_and(
        jnp.logical_not(is_chunk),
        jnp.logical_and(bt_r_ref[jnp.minimum(j, nblk - 1)] >= 0,
                        j * ps < start))
    if window is not None:
        ctx_live = jnp.logical_and(ctx_live,
                                   (j + 1) * ps > start + 1 - window)
    chunk_live = jnp.logical_and(is_chunk, cp * ps < length)

    qp = start + jax.lax.broadcasted_iota(jnp.int32, (1, 1, c, 1), 2)

    def _tile(k, v, valid):
        """One online-softmax accumulation step over a (ps,) key tile."""
        q = q_ref[...]                       # (bh, rep, C, dhp)
        s = jax.lax.dot_general(             # (bh, rep, C, ps)
            q, k, (((3,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        s = s.astype(jnp.float32) * sm_scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((3,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ctx_live)
    def _context():
        kp = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, ps), 3)
        valid = kp < start                   # context is strictly pre-chunk
        if window is not None:
            valid = jnp.logical_and(valid, qp - kp < window)
        _tile(kp_ref[0, 0], vp_ref[0, 0], valid)

    @pl.when(chunk_live)
    def _chunk():
        kp = (start + cp * ps
              + jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, ps), 3))
        valid = jnp.logical_and(kp <= qp, kp < start + length)
        if window is not None:
            valid = jnp.logical_and(valid, qp - kp < window)
        _tile(kn_ref[0], vn_ref[0], valid)

    # ---- fused scatter: chunk K/V tiles land in their pool pages -----
    # (context steps map to the dump page — see the write index map —
    # so the unconditional store never touches live pages there)
    ko_ref[0, 0] = kn_ref[0]
    vo_ref[0, 0] = vn_ref[0]

    @pl.when(j == nblk + ncp - 1)
    def _finalize():
        o_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("layer", "window", "softcap",
                                             "bh", "interpret"))
def paged_prefill(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                  k_pool: jax.Array, v_pool: jax.Array,
                  bt_read: jax.Array, bt_write: jax.Array,
                  start, length, *, layer: int,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  bh: Optional[int] = None,
                  interpret: bool = True):
    """Fused chunk prefill: scatter + causal flash attention over pages.

    q (C, hq, dh); k_new/v_new (C, hkv, dh) ALREADY cast to the pool
    dtype; k_pool/v_pool (L, P+1, ps, hkv, dh_pool) — the last physical
    page is the dump page for masked writes; bt_read (nblk,) the
    request's block table; bt_write (nblk,) its writable row (shared
    blocks -1); start int32 page-aligned chunk origin; length int32 live
    tokens in the chunk (1..C).  Returns ``(o, k_pool', v_pool')`` with
    o (C, hq, dh) f32 — rows past ``length`` are garbage (masked
    queries) and must not be consumed.
    """
    c, hq, dh = q.shape
    nlayers, pp, ps, hkv, dhp = k_pool.shape
    nblk = bt_read.shape[0]
    rep = hq // hkv
    if hq % hkv:
        raise ValueError(f"hq={hq} not a multiple of hkv={hkv}")
    if c % ps:
        raise ValueError(f"chunk {c} not a multiple of page size {ps}")
    ncp = c // ps
    dump = pp - 1
    sm_scale = 1.0 / math.sqrt(dh)
    if dhp > dh:
        padw = ((0, 0), (0, 0), (0, dhp - dh))
        q = jnp.pad(q, padw)
        k_new, v_new = jnp.pad(k_new, padw), jnp.pad(v_new, padw)
    if bh is None:
        choice = autotune.choose_prefill_blocks(c, hkv, rep, dhp, ps)
        if choice is None:
            raise ValueError(
                f"no feasible paged-prefill blocks for (C, hkv, rep, dh, ps)"
                f"=({c}, {hkv}, {rep}, {dhp}, {ps}); route through "
                f"repro.models.layers.attention_prefill_paged for the XLA "
                f"fallback")
        bh = choice.bh
    if hkv % bh:
        raise ValueError(f"bh={bh} must divide hkv={hkv}")
    qg = q.reshape(c, hkv, rep, dhp).transpose(1, 2, 0, 3)  # (hkv,rep,C,dhp)
    knt = k_new.reshape(ncp, ps, hkv, dhp)
    vnt = v_new.reshape(ncp, ps, hkv, dhp)
    meta = jnp.asarray(
        jnp.stack([jnp.asarray(start, jnp.int32),
                   jnp.asarray(length, jnp.int32)]), jnp.int32)
    grid = (hkv // bh, nblk + ncp)
    start_page = jnp.asarray(start, jnp.int32) // ps

    def q_map(hg, j, bt_r, bt_w, m):
        return (hg, 0, 0, 0)

    def kn_map(hg, j, bt_r, bt_w, m):
        return (jnp.clip(j - nblk, 0, ncp - 1), 0, hg, 0)

    def kv_in_map(hg, j, bt_r, bt_w, m):
        # context fetch contract (see ctx_block_index): dead/chunk steps
        # clamp onto an already-fetched page -> no new DMA
        return (layer, ctx_block_index(j, bt_r, m[0], ps=ps, nblk=nblk,
                                       window=window), 0, hg, 0)

    def kv_out_map(hg, j, bt_r, bt_w, m):
        # chunk steps write their page (masked / dead pages and every
        # context step go to the dump page)
        cp = j - nblk
        page = bt_w[jnp.clip(m[0] // ps + cp, 0, nblk - 1)]
        live = jnp.logical_and(j >= nblk,
                               jnp.logical_and(cp * ps < m[1], page >= 0))
        return (layer, jnp.where(live, page, dump), 0, hg, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bh, rep, c, dhp), q_map),
            pl.BlockSpec((1, ps, bh, dhp), kn_map),
            pl.BlockSpec((1, ps, bh, dhp), kn_map),
            pl.BlockSpec((1, 1, ps, bh, dhp), kv_in_map),
            pl.BlockSpec((1, 1, ps, bh, dhp), kv_in_map),
        ],
        out_specs=[
            pl.BlockSpec((bh, rep, c, dhp), q_map),
            pl.BlockSpec((1, 1, ps, bh, dhp), kv_out_map),
            pl.BlockSpec((1, 1, ps, bh, dhp), kv_out_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((bh, rep, c, 1), jnp.float32),     # running max
            pltpu.VMEM((bh, rep, c, 1), jnp.float32),     # running denom
            pltpu.VMEM((bh, rep, c, dhp), jnp.float32),   # weighted-V acc
        ],
    )
    o, k_pool, v_pool = pl.pallas_call(
        functools.partial(_kernel, ps=ps, nblk=nblk, ncp=ncp, c=c,
                          sm_scale=sm_scale, window=window, softcap=softcap),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((hkv, rep, c, dhp), jnp.float32),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        # operand numbering includes the scalar-prefetch args: the pools
        # (inputs 6/7) alias outputs 1/2 so chunk pages update in place
        input_output_aliases={6: 1, 7: 2},
        interpret=interpret,
    )(bt_read.astype(jnp.int32), bt_write.astype(jnp.int32), meta,
      qg, knt, vnt, k_pool, v_pool)
    o = o.transpose(2, 0, 1, 3).reshape(c, hq, dhp)[..., :dh]
    return o, k_pool, v_pool


def paged_prefill_xla(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                      k_pool: jax.Array, v_pool: jax.Array,
                      bt_read: jax.Array, bt_write: jax.Array,
                      start, length, *, layer: int,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None):
    """Dense-gather reference/fallback for :func:`paged_prefill`.

    Gathers every context page into a dense tile stack and accumulates
    the SAME online-softmax recurrence over the SAME page-tile order
    with the same dot_general calls, so in f32 it matches the kernel
    bit-exactly (the oracle the tests pin) while still writing the
    chunk's pages through the masked scatter.  The dense (nblk*ps)
    gather buffer is exactly the intermediate the kernel avoids.
    """
    c, hq, dh = q.shape
    nlayers, pp, ps, hkv, dhp = k_pool.shape
    nblk = bt_read.shape[0]
    rep = hq // hkv
    ncp = c // ps
    dump = pp - 1
    sm_scale = 1.0 / math.sqrt(dh)
    start = jnp.asarray(start, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    if dhp > dh:
        padw = ((0, 0), (0, 0), (0, dhp - dh))
        q = jnp.pad(q, padw)
        k_new, v_new = jnp.pad(k_new, padw), jnp.pad(v_new, padw)

    # ---- fused-write mirror: full chunk-page tiles, dump for masked --
    idx = jnp.arange(c, dtype=jnp.int32)
    cp = idx // ps
    page = bt_write[jnp.clip(start // ps + cp, 0, nblk - 1)]
    live_w = jnp.logical_and(cp * ps < length, page >= 0)
    page = jnp.where(live_w, page, dump)
    slot = idx % ps
    k_pool = k_pool.at[layer, page, slot].set(k_new)
    v_pool = v_pool.at[layer, page, slot].set(v_new)

    # ---- attend: context page tiles then in-chunk tiles --------------
    ctx_pages = jnp.clip(bt_read, 0)
    kt = jnp.concatenate([k_pool[layer][ctx_pages],
                          k_new.reshape(ncp, ps, hkv, dhp)])
    vt = jnp.concatenate([v_pool[layer][ctx_pages],
                          v_new.reshape(ncp, ps, hkv, dhp)])
    qg = q.reshape(c, hkv, rep, dhp).transpose(1, 2, 0, 3)
    qp = start + jax.lax.broadcasted_iota(jnp.int32, (1, 1, c, 1), 2)

    def step(carry, xs):
        m_prev, l_prev, acc_prev = carry
        k, v, j = xs
        is_chunk = j >= nblk
        cpj = jnp.maximum(j - nblk, 0)
        live = jnp.where(
            is_chunk, cpj * ps < length,
            jnp.logical_and(bt_read[jnp.minimum(j, nblk - 1)] >= 0,
                            j * ps < start))
        base = jnp.where(is_chunk, start + cpj * ps, j * ps)
        if window is not None:
            live = jnp.logical_and(
                live, jnp.logical_or(is_chunk,
                                     (j + 1) * ps > start + 1 - window))
        kp = base + jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, ps), 3)
        valid = jnp.where(is_chunk,
                          jnp.logical_and(kp <= qp, kp < start + length),
                          kp < start)
        if window is not None:
            valid = jnp.logical_and(valid, qp - kp < window)
        s = jax.lax.dot_general(qg, k, (((3,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        s = s.astype(jnp.float32) * sm_scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, -1, keepdims=True)
        acc_new = acc_prev * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((3,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        keep = lambda new, old: jnp.where(live, new, old)
        return (keep(m_new, m_prev), keep(l_new, l_prev),
                keep(acc_new, acc_prev)), None

    m0 = jnp.full((hkv, rep, c, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((hkv, rep, c, 1), jnp.float32)
    a0 = jnp.zeros((hkv, rep, c, dhp), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kt, vt, jnp.arange(nblk + ncp, dtype=jnp.int32)))
    o = acc / jnp.maximum(l, 1e-30)
    o = o.transpose(2, 0, 1, 3).reshape(c, hq, dhp)[..., :dh]
    return o, k_pool, v_pool
