"""Jit'd public wrappers dispatching QLinear forwards to Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` for
correctness; on TPU set ``repro.kernels.ops.INTERPRET = False`` (the
launcher does this when ``jax.default_backend() == 'tpu'``).

Decode fast path notes (§Perf):

* Feasibility is checked BEFORE the salient-first activation gather, so
  an unaligned-shape call falls back to the XLA dequant path without
  paying a dead (M, K) gather first.
* Block sizes come from the :mod:`repro.kernels.autotune` cost model
  (memoized per shape — the dispatch cache), not fixed constants: decode
  calls at M = n_slots get M-sized row blocks and, VMEM permitting, a
  whole-N column block so the activation streams HBM→VMEM once per call.
* ``pre_permuted=True`` skips the gather entirely for callers that
  already hold salient-first activations — the N-fused QLinearGroup path
  gathers once per group (QKV, gate+up) instead of once per projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.binary_matmul import binary_matmul
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.mixed_matmul import mixed_matmul as _mixed
from repro.kernels.paged_attention import paged_attention as _paged_attn
from repro.kernels.paged_prefill import paged_prefill as _paged_prefill
from repro.kernels.paged_prefill import paged_prefill_xla

INTERPRET = jax.default_backend() != "tpu"


def _kernel_choice(m: int, k_s: int, k_b: int, n: int):
    """Autotuned blocks, or None when the kernel cannot serve the shape
    (misaligned N, no common K block, or an empty int4/binary span —
    the kernel's block specs need at least one step on each span)."""
    if k_s <= 0 or k_b <= 0:
        return None
    return autotune.choose_blocks(m, k_s, k_b, n)


def mixed_matmul(x: jax.Array, q, *, pre_permuted: bool = False) -> jax.Array:
    """PTQ1.61 linear forward for a QLinear `q` (2-D weights).

    Flattens batch dims, checks kernel feasibility, then runs the fused
    kernel with autotuned blocks; falls back to the XLA dequant path for
    unaligned shapes.  The salient-first channel permutation happens
    INSIDE the kernel when the full-K activation tile fits VMEM (the
    perm rides in as a scalar-prefetch operand — no host-side gather at
    all); otherwise one XLA gather precedes the call.  With
    ``pre_permuted=True`` the caller asserts ``x`` is already in
    salient-first channel order and no gather is issued on any path.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    m = 1
    for d in lead:
        m *= d
    choice = _kernel_choice(m, q.k_s, q.k_b, q.n)
    if choice is None:
        if pre_permuted:
            return q.__matmul_permuted__(x)
        import dataclasses
        return dataclasses.replace(q, use_kernel=False).__matmul_x__(x)
    xf = x.reshape(-1, k)
    perm = None
    if pre_permuted:
        xp = xf
    elif INTERPRET and autotune.gather_in_kernel_ok(choice, m, k):
        # gather moves into the kernel (scalar-prefetched perm).  Pinned
        # to interpret mode for now: the dynamic lane-dim jnp.take over
        # SMEM-sliced indices is unvalidated under Mosaic lowering — on
        # a real TPU the host-side gather below stays until it is.
        xp, perm = xf, q.perm
    else:
        xp = jnp.take(xf, q.perm, axis=-1)
    alpha_out = (q.alpha_s * q.alpha_r1).astype(jnp.float32)
    y = _mixed(xp.astype(jnp.bfloat16), q.w4, q.s4, q.z4, q.bits,
               alpha_out, q.alpha_r2.astype(jnp.float32), perm=perm,
               bm=choice.bm, bn=choice.bn, bk=choice.bk,
               interpret=INTERPRET)
    return y.reshape(lead + (q.n,)).astype(x.dtype)


LANE = 128      # TPU register-tile lane width (last-dim tiling floor)


def padded_head_dim(dh: int) -> int:
    """Head dim the paged KV *pool* allocates for a logical ``dh``.

    On a real TPU the flash-decode kernel's K/V page tiles must land on
    the 128-lane register tiling, so pools for archs with
    ``dh % 128 != 0`` are rounded up and the tail zero-padded — exact,
    because zero lanes add nothing to q·k (contraction dim) and the
    padded output columns are sliced off before the output projection.
    Interpret mode keeps the logical dh (no constraint, no memory tax);
    tests monkeypatch this to exercise the padded layout on CPU."""
    if INTERPRET or dh % LANE == 0:
        return dh
    return ((dh + LANE - 1) // LANE) * LANE


def paged_attention_blocks(ps: int, hkv: int, rep: int, dh: int,
                           pool_dh: int = None):
    """Feasibility gate for the paged flash-decode kernel: the
    autotuned KV-tile choice, or None when the kernel cannot serve the
    shape and the caller must keep the XLA-gather reference path.  On a
    real TPU backend the pool layout must respect the MXU/VPU tiling
    floors — ``dh`` misalignment is absorbed by the pool's padded head
    dim (:func:`padded_head_dim`; ``pool_dh`` is the pool's actual last
    dim when the caller holds the cache), leaving only the page-size
    sublane floor; interpret mode has no such constraint."""
    pool_dh = padded_head_dim(dh) if pool_dh is None else pool_dh
    if pool_dh < dh:
        return None
    if not INTERPRET and (pool_dh % LANE != 0 or ps % 8 != 0):
        return None
    return autotune.choose_paged_blocks(hkv, rep, pool_dh, ps)


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, context_lens: jax.Array, *,
                    window=None, softcap=None, bh=None) -> jax.Array:
    """Paged flash-decode forward (see kernels.paged_attention); the
    caller is expected to have consulted :func:`paged_attention_blocks`
    first — this wrapper only pins the interpret mode."""
    return _paged_attn(q, k_pool, v_pool, block_tables, context_lens,
                       window=window, softcap=softcap, bh=bh,
                       interpret=INTERPRET)


def paged_prefill_blocks(c: int, ps: int, hkv: int, rep: int, dh: int,
                         pool_dh: int = None):
    """Feasibility gate for the chunked paged-prefill kernel: the
    autotuned KV-tile choice, or None when the kernel cannot serve the
    shape and the caller must keep the XLA dense-gather fallback
    (:func:`repro.kernels.paged_prefill.paged_prefill_xla`).  Same
    tiling-floor rules as :func:`paged_attention_blocks`, plus the
    chunk must tile evenly into pages."""
    pool_dh = padded_head_dim(dh) if pool_dh is None else pool_dh
    if pool_dh < dh or c % ps:
        return None
    if not INTERPRET and (pool_dh % LANE != 0 or ps % 8 != 0):
        return None
    return autotune.choose_prefill_blocks(c, hkv, rep, pool_dh, ps)


def paged_prefill(q, k_new, v_new, k_pool, v_pool, bt_read, bt_write,
                  start, length, *, layer, window=None, softcap=None,
                  bh=None):
    """Fused chunk scatter+attend (see kernels.paged_prefill); the
    caller is expected to have consulted :func:`paged_prefill_blocks`
    first — this wrapper only pins the interpret mode."""
    return _paged_prefill(q, k_new, v_new, k_pool, v_pool, bt_read,
                          bt_write, start, length, layer=layer,
                          window=window, softcap=softcap, bh=bh,
                          interpret=INTERPRET)


__all__ = ["binary_matmul", "int4_matmul", "mixed_matmul",
           "paged_attention", "paged_attention_blocks",
           "paged_prefill", "paged_prefill_blocks", "paged_prefill_xla",
           "padded_head_dim", "LANE", "INTERPRET", "autotune"]
