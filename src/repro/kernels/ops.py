"""Jit'd public wrappers dispatching QLinear forwards to Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` for
correctness; on TPU set ``repro.kernels.ops.INTERPRET = False`` (the
launcher does this when ``jax.default_backend() == 'tpu'``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.binary_matmul import binary_matmul
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.mixed_matmul import mixed_matmul as _mixed

INTERPRET = jax.default_backend() != "tpu"


def _block_ok(k_s: int, k_b: int, n: int, bk: int = 128) -> bool:
    return (k_s % bk == 0) and (k_b % bk == 0) and (n % 128 == 0)


def mixed_matmul(x: jax.Array, q) -> jax.Array:
    """PTQ1.61 linear forward for a QLinear `q` (2-D weights).

    Flattens batch dims, permutes channels salient-first, runs the fused
    kernel; falls back to the XLA dequant path for unaligned shapes.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    xp = jnp.take(x.reshape(-1, k), q.perm, axis=-1)
    if not _block_ok(q.k_s, q.k_b, q.n):
        import dataclasses
        from repro.core.qlinear import QLinear
        return dataclasses.replace(q, use_kernel=False).__matmul_x__(x)
    alpha_out = (q.alpha_s * q.alpha_r1).astype(jnp.float32)
    y = _mixed(xp.astype(jnp.bfloat16), q.w4, q.s4, q.z4, q.bits,
               alpha_out, q.alpha_r2.astype(jnp.float32),
               interpret=INTERPRET)
    return y.reshape(lead + (q.n,)).astype(x.dtype)


__all__ = ["binary_matmul", "int4_matmul", "mixed_matmul", "INTERPRET"]
