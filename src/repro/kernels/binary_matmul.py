"""Pallas TPU kernel: packed 1-bit × bf16 matmul with Eq.-9 scales.

The decode-time hot spot of a sub-2-bit-quantized LLM: weights stream
HBM→VMEM as PACKED bytes (K/8 the footprint of bf16), unpack to ±1 bf16
inside VMEM, and feed the MXU as a dense matmul.  There is no TPU
XNOR-popcount datapath (DESIGN.md §3) — the win is the 16× weight-byte
reduction on a bandwidth-bound op, not the multiply itself.

Tiling: grid (M/bm, N/bn, K/bk); K innermost for accumulation.
  x tile     (bm, bk)     bf16
  bits tile  (bk/8, bn)   u8     -> unpack -> (bk, bn) ±1 bf16
  acc        (bm, bn)     f32 in the output ref (revisited across K steps)
Block sizes default to the :mod:`repro.kernels.autotune` cost model
(VMEM-budgeted, HBM-byte-minimizing per (M, K, N)); decode-shaped calls
get bm=M and a whole-N column block so the activation streams once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune


def _unpack_bits_block(packed: jax.Array, bk: int, bn: int) -> jax.Array:
    """(bk//8, bn) u8 -> (bk, bn) bf16 ±1 (bit j of byte i -> k=8i+j)."""
    p = packed.astype(jnp.int32)                     # (bk/8, bn)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 8, 1), 1)
    bits = (p[:, None, :] >> shifts) & 1             # (bk/8, 8, bn)
    return (bits.reshape(bk, bn) * 2 - 1).astype(jnp.bfloat16)


def _kernel(x_ref, bits_ref, a_in_ref, a_out_ref, o_ref, *, bk, bn):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32) * a_in_ref[...][None, :]
    sign = _unpack_bits_block(bits_ref[...], bk, bn)
    acc = jax.lax.dot(x.astype(jnp.bfloat16), sign,
                      preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == pl.num_programs(2) - 1)
    def _scale():
        o_ref[...] = o_ref[...] * a_out_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def binary_matmul(x: jax.Array, bits: jax.Array, alpha_out: jax.Array,
                  alpha_in: jax.Array, *, bm: int = None, bn: int = None,
                  bk: int = None, interpret: bool = True) -> jax.Array:
    """y (M,N) f32 = ((x·α_in) @ unpack(bits)) · α_out.

    Block sizes default to the :mod:`repro.kernels.autotune` cost model
    (decode-shaped M picks bm=M and, VMEM permitting, bn=N); explicit
    values are clamped/repaired to feasible divisors.
    """
    m, kdim = x.shape
    n = bits.shape[1]
    if bits.shape[0] * 8 != kdim:
        raise ValueError(f"bits K span {bits.shape[0] * 8} != x K {kdim}")
    bm, bn, bk = autotune.resolve_blocks(m, 0, kdim, n, bm, bn, bk)
    if bk is None or m % bm or n % bn or kdim % bk or bk % 8:
        raise ValueError(
            f"infeasible binary blocks (bm,bn,bk)=({bm},{bn},{bk}) for "
            f"(M,K,N)=({m},{kdim},{n})")

    grid = (m // bm, n // bn, kdim // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 8, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, bits, alpha_in.astype(jnp.float32), alpha_out.astype(jnp.float32))
    return out.astype(x.dtype)
