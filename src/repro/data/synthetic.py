"""Deterministic synthetic corpus with learnable structure.

No WikiText2/C4/RedPajama in this offline container (DESIGN.md §8), so
calibration, preprocessing and PPL evaluation run on a mixture of Zipfian
bigram processes: each "document" samples a latent topic which selects a
bigram transition table over a Zipf-distributed vocabulary.  The process
has real mutual information between adjacent tokens, so cross-entropy
deltas between FP and quantized models are meaningful (a collapsed model
regresses to the unigram entropy, a good model approaches the bigram
entropy).

Everything is a pure function of (seed, split) — reproducible across
hosts, shardable by slicing the document index space (host i of H reads
documents ≡ i mod H), no files.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass(frozen=True)
class CorpusConfig:
    vocab: int = 2048
    n_topics: int = 8
    branch: int = 24          # out-degree of each bigram row
    zipf_a: float = 1.2
    seed: int = 1234


class SyntheticCorpus:
    """Topic-mixture Zipfian bigram language."""

    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, t, b = cfg.vocab, cfg.n_topics, cfg.branch
        # per-topic bigram tables: for each token, `branch` successors with
        # Zipf weights (sparse representation -> cheap sampling)
        self.succ = rng.integers(0, v, size=(t, v, b), dtype=np.int32)
        w = 1.0 / np.arange(1, b + 1) ** cfg.zipf_a
        self.succ_p = (w / w.sum()).astype(np.float64)
        # Zipfian unigram start distribution
        uw = 1.0 / np.arange(1, v + 1) ** cfg.zipf_a
        self.start_p = uw / uw.sum()

    def document(self, doc_id: int, length: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, doc_id))
        topic = rng.integers(0, self.cfg.n_topics)
        toks = np.empty(length, np.int32)
        toks[0] = rng.choice(self.cfg.vocab, p=self.start_p)
        branches = rng.choice(self.cfg.branch, size=length - 1, p=self.succ_p)
        tbl = self.succ[topic]
        for i in range(1, length):
            toks[i] = tbl[toks[i - 1], branches[i - 1]]
        return toks

    def batches(self, batch: int, seq: int, n_batches: int, *,
                split: str = "train", host: int = 0, n_hosts: int = 1
                ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yield (tokens, targets) (B,S) int32.  Deterministic per (split,
        batch index); hosts read disjoint document ids (data sharding)."""
        base = {"train": 0, "valid": 10_000_000, "calib": 20_000_000}[split]
        for i in range(n_batches):
            docs = []
            for j in range(batch):
                doc_id = base + (i * batch + j) * n_hosts + host
                docs.append(self.document(doc_id, seq + 1))
            arr = np.stack(docs)
            yield arr[:, :-1].copy(), arr[:, 1:].copy()

    def bigram_ceiling_ppl(self, n: int = 20000) -> float:
        """Entropy of the generating bigram process ≈ best achievable PPL."""
        h = -np.sum(self.succ_p * np.log(self.succ_p))
        return float(np.exp(h))


def calibration_set(corpus: SyntheticCorpus, n_segments: int = 128,
                    seq: int = 2048, batch: int = 1):
    """The paper's calibration protocol: 128 random 2048-token segments
    (WikiText2 there, synthetic here), batch size 1."""
    return list(corpus.batches(batch, seq, n_segments // batch, split="calib"))
