"""Minimal-but-production AdamW (decoupled weight decay) + schedules.

Used by: the training launcher, PTQ1.61 block-wise scale optimization
(paper: AdamW, zero weight decay, lr 5e-4/1e-3), and restorative-LoRA
preprocessing.  Pure pytree-in/pytree-out; state shards like params.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Tree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Tree
    nu: Tree


@dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None
    # dtype for first/second moments; fp32 master moments by default
    state_dtype: Any = jnp.float32

    def init(self, params: Tree) -> AdamWState:
        z = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(z, params),
                          nu=jax.tree.map(z, params))

    def update(self, grads: Tree, state: AdamWState,
               params: Tree) -> tuple[Tree, AdamWState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        lr = self.lr if self.schedule is None else self.lr * self.schedule(step)

        def upd(g, m, v, p):
            gf = g.astype(self.state_dtype)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            mhat = m / (1 - b1 ** step.astype(self.state_dtype))
            vhat = v / (1 - b2 ** step.astype(self.state_dtype))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(self.state_dtype)
            return (p.astype(self.state_dtype) - lr * delta).astype(p.dtype), m, v

        # flatten/unflatten (not a tuple-leaf tree_map) because param trees
        # may legitimately contain tuple nodes (scanned stage patterns)
        g_l, treedef = jax.tree.flatten(grads)
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(g_l, jax.tree.leaves(state.mu), jax.tree.leaves(state.nu),
                   jax.tree.leaves(params))]
        new_params = jax.tree.unflatten(treedef, [t[0] for t in out])
        mu = jax.tree.unflatten(treedef, [t[1] for t in out])
        nu = jax.tree.unflatten(treedef, [t[2] for t in out])
        return new_params, AdamWState(step, mu, nu)


def global_norm(tree: Tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def cosine_schedule(warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / max(1, warmup)
        prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return fn
