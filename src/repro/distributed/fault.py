"""Fault tolerance & elasticity for the training launcher.

Mechanisms (exercised by tests/test_fault_tolerance.py and
launch/train.py):

* **Checkpoint/restart** — atomic sharded checkpoints every
  ``save_every`` steps (repro.checkpoint); on any step failure the
  supervisor restores the latest manifest and resumes.  Data order is a
  pure function of the step counter (repro.data.synthetic), so restarts
  are bit-deterministic — no replayed or skipped batches.

* **Failure injection** — ``FailureInjector`` raises at configured steps
  (simulating a dead host); the supervisor's retry loop demonstrates the
  restart path end-to-end in CI.

* **Elastic re-mesh** — checkpoints store arrays UNSHARDED per-leaf, so a
  restart may resume on a different device count / mesh shape (e.g. a pod
  drops out: (pod=2,…) → (16,16)).  `launch/train.py --remesh` covers it.

* **Straggler mitigation** — a step-time watchdog tracks a running
  median; steps slower than ``threshold ×`` median are logged and counted
  (on real fleets this signal feeds preemption/rescheduling; here it
  drives the log + metrics only).  Since data sharding is deterministic
  by (host, step), a replacement host can skip ahead without coordination.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


@dataclass
class StragglerWatchdog:
    threshold: float = 3.0
    _times: List[float] = field(default_factory=list)
    slow_steps: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float, log=print):
        self._times.append(dt)
        if len(self._times) < 5:
            return
        med = sorted(self._times[-50:])[len(self._times[-50:]) // 2]
        if dt > self.threshold * med:
            self.slow_steps.append(step)
            log(f"[straggler] step {step} took {dt*1e3:.1f}ms "
                f"(median {med*1e3:.1f}ms)")


class Supervisor:
    """Retry loop around a training step with checkpoint restore."""

    def __init__(self, restore_fn: Callable[[], int],
                 max_restarts: int = 3, log=print):
        self.restore_fn = restore_fn
        self.max_restarts = max_restarts
        self.restarts = 0
        self.log = log

    def run(self, step_fn: Callable[[int], None], start: int, end: int):
        step = start
        while step < end:
            try:
                step_fn(step)
                step += 1
            except InjectedFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.log(f"[fault] {e} — restoring from checkpoint "
                         f"(restart {self.restarts}/{self.max_restarts})")
                step = self.restore_fn()
        return step
