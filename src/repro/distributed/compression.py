"""Gradient compression with error feedback (DP all-reduce path).

Under GSPMD the data-parallel gradient all-reduce is implicit, so the
compressor runs as a quantize→dequantize transform on the gradient tree
with an error-feedback residual carried in the train state.  Because the
transform is deterministic and identical on every replica, applying it to
the (already averaged) gradient is mathematically equivalent to
compressing the per-replica contributions of a compressed all-reduce —
the standard EF-SGD equivalence (Karimireddy et al., 2019).

Two compressors:
  * ``int8``: per-tensor absmax int8 (8× wire reduction)
  * ``topk``: magnitude top-k% sparsification (k default 10%)
Both converge to the uncompressed optimum thanks to error feedback.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclass(frozen=True)
class CompressionConfig:
    kind: Optional[str] = None     # None | "int8" | "topk"
    topk_frac: float = 0.1


def init_residual(grads: Tree) -> Tree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _int8_qdq(g: jax.Array) -> jax.Array:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    return jnp.round(g / scale).clip(-127, 127) * scale


def _topk_qdq(g: jax.Array, frac: float) -> jax.Array:
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress(grads: Tree, residual: Tree,
             ccfg: CompressionConfig) -> Tuple[Tree, Tree]:
    """(compressed grads, new residual).  No-op when kind is None."""
    if ccfg.kind is None:
        return grads, residual

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if ccfg.kind == "int8":
            dq = _int8_qdq(gf)
        elif ccfg.kind == "topk":
            dq = _topk_qdq(gf, ccfg.topk_frac)
        else:
            raise ValueError(ccfg.kind)
        return dq.astype(g.dtype), gf - dq

    # flatten/unflatten — gradient trees may contain tuple nodes (stages)
    g_l, treedef = jax.tree.flatten(grads)
    out = [one(g, r) for g, r in zip(g_l, jax.tree.leaves(residual))]
    newg = jax.tree.unflatten(treedef, [t[0] for t in out])
    newr = jax.tree.unflatten(treedef, [t[1] for t in out])
    return newg, newr


def wire_bytes(grads: Tree, ccfg: CompressionConfig) -> int:
    """Bytes a compressed DP all-reduce would move per replica."""
    total = 0
    for g in jax.tree.leaves(grads):
        if ccfg.kind == "int8":
            total += g.size + 4
        elif ccfg.kind == "topk":
            total += int(g.size * ccfg.topk_frac) * (4 + 4)
        else:
            total += g.size * 4
    return total
