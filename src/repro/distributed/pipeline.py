"""GPipe-style pipeline parallelism over ``shard_map`` + ``lax.ppermute``.

Optional stage-parallel execution (DESIGN.md §5): stages live on
consecutive ranks of a mesh axis; microbatches flow through a
(n_micro + n_stages − 1)-tick schedule with activations handed to the
next stage by collective-permute each tick.

This is a self-contained engine (covered by tests/test_pipeline.py with a
sequential-equality oracle); the dry-run meshes default to DP×TP with the
"pod" axis as outer DP, but any stage-sliceable block stack can run
through `pipeline_apply` on a ("stage", …) mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

Tree = Any


def pipeline_apply(block_fn: Callable[[Tree, jax.Array], jax.Array],
                   stage_params: Tree, x_micro: jax.Array, mesh,
                   axis: str = "stage") -> jax.Array:
    """Run `y = stageS-1(…stage0(x))` with stages sharded over `axis`.

    stage_params: leaves (n_stages, …), sharded on dim 0 over `axis`.
    x_micro: (n_micro, mb, …) microbatched input (replicated).
    Returns (n_micro, mb, …) outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_local, x_local):
        # params_local: (1, …) this rank's stage; x_local: full microbatches
        params1 = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)

        def tick(carry, t):
            recv, outs = carry
            # stage 0 ingests microbatch t (while valid); others take recv
            mb = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(idx == 0, x_local[mb], recv)
            y = block_fn(params1, x_in)
            # the last stage emits microbatch (t - n_stages + 1)
            out_t = t - (n_stages - 1)
            valid = jnp.logical_and(idx == n_stages - 1,
                                    jnp.logical_and(out_t >= 0,
                                                    out_t < n_micro))
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[jnp.clip(out_t, 0, n_micro - 1)].set(y),
                lambda o: o, outs)
            recv = jax.lax.ppermute(y, axis, perm)
            return (recv, outs), None

        recv0 = jnp.zeros_like(x_local[0])
        outs0 = jnp.zeros_like(x_local)
        (_, outs), _ = jax.lax.scan(tick, (recv0, outs0),
                                    jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: PS(axis), stage_params)
    from repro.models.common import shard_map_compat
    fn = shard_map_compat(local, mesh=mesh,
                          in_specs=(pspec, PS()), out_specs=PS())
    return fn(stage_params, x_micro)
