"""Logical-axis → PartitionSpec translation (DESIGN.md §5).

One rule table turns every parameter/cache/optimizer-state tree into a
PartitionSpec tree for any mesh:

  vocab/heads/kv_heads/ffn/rnn → "model"            (tensor parallel)
  experts                      → "model" (EP) or fall through to ffn-TP
  embed                        → ("pod","data") under FSDP else replicated
  batch                        → ("pod","data")     (data parallel)
  layers / None                → replicated (scan dim / small vectors)

Conflicts (same mesh axis appearing twice in one spec — e.g. expert-
sharded (experts, embed, ffn) weights under EP+TP) resolve first-come:
later dims degrade to replicated, matching Megatron/MaxText practice.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as PS

from repro.core.qlinear import QLinear, field_axes
from repro.models.param import P, is_leaf as is_p

Tree = Any


@dataclass(frozen=True)
class Rules:
    """Sharding rule table; build per run from mesh + parallel config."""

    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)      # ("pod","data") multi-pod
    fsdp: bool = False
    ep: bool = False                          # shard MoE expert dim

    def axis_map(self) -> Dict[Optional[str], Any]:
        m: Dict[Optional[str], Any] = {
            "vocab": self.tp_axis,
            "heads": self.tp_axis,
            "kv_heads": self.tp_axis,
            "ctx": self.tp_axis,      # context-sharded KV cache windows
            "ffn": self.tp_axis,
            "rnn": self.tp_axis,
            "experts": self.tp_axis if self.ep else None,
            "embed": self.dp_axes if self.fsdp else None,
            "batch": self.dp_axes,
            "layers": None,
            None: None,
        }
        return m

    def spec(self, axes: Tuple[Optional[str], ...]) -> PS:
        amap = self.axis_map()
        used = set()
        out = []
        for a in axes:
            mesh_ax = amap.get(a, None)
            flat = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax or ())
            if any(f in used for f in flat) or not flat:
                out.append(None)
            else:
                used.update(flat)
                out.append(mesh_ax if isinstance(mesh_ax, str) else tuple(flat))
        return PS(*out)


def rules_for_mesh(mesh, *, fsdp: bool = False, ep: bool = False) -> Rules:
    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    return Rules(tp_axis="model", dp_axes=dp, fsdp=fsdp, ep=ep)


def specs_for_tree(declared: Tree, rules: Rules) -> Tree:
    """P declaration tree -> PartitionSpec tree (same structure).

    Quantized (QLinear) declarations are handled by
    ``repro.launch.qdeclare.declare_quantized``, which emits the spec tree
    in the same pass that builds the abstract QLinears.
    """
    def visit(leaf):
        if is_p(leaf):
            return rules.spec(leaf.axes)
        raise TypeError(f"specs_for_tree expects P leaves, got {type(leaf)}")
    return jax.tree.map(visit, declared, is_leaf=is_p)


def qlinear_specs(p_axes: Tuple, k_s: int, k: int, n: int, rules: Rules,
                  use_kernel: bool = False) -> QLinear:
    """PartitionSpec-QLinear for a weight declared with axes `p_axes`
    (prefix…, in_axis, out_axis)."""
    prefix, in_ax, out_ax = p_axes[:-2], p_axes[-2], p_axes[-1]
    fa = field_axes(prefix, in_ax, out_ax)
    return QLinear(**{key: rules.spec(v) for key, v in fa.items()},
                   k_s=k_s, k=k, n=n, use_kernel=use_kernel)


def named_shardings(mesh, spec_tree: Tree) -> Tree:
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, PS))
