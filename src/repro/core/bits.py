"""Average bits-per-weight accounting (paper Appendix A).

    b = 1·r_b + b_salient·(1−r_b) + b_index + b_additional

* weight bits: binary channels at 1 bit, salient at 4;
* b_index: the 1-D structured mask is K bits per (K,N) matrix
  (≈0.0002 b/w at 4096² — the salient-first permutation is derivable from
  the mask, costing nothing extra);
* b_additional: fp16 scale storage — α_s, α_r1 (N each), α_r2 (k_b),
  int4 per-channel scale+zero (2·k_s).

For reference, the same accounting applied to the baselines (App. A):
PB-LLM 0.1·8 + 0.9·1 + 1(unstructured mask) = 2.7 b/w; BiLLM 1.0 + 0.1 +
1.0 = 2.1 b/w.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List

import jax
import numpy as np

from repro.core.qlinear import QLinear

Tree = Any
SCALE_BITS = 16


@dataclass(frozen=True)
class BitsReport:
    weight_bits: float       # 1·r_b + 4·(1-r_b)
    index_bits: float        # structured mask
    additional_bits: float   # scales + zero points
    total_bits: float
    n_weights: int

    def row(self) -> str:
        return (f"{self.weight_bits:.4f} + {self.index_bits:.6f} + "
                f"{self.additional_bits:.4f} = {self.total_bits:.4f}")


def qlinear_bits(q: QLinear) -> BitsReport:
    lead = int(np.prod(q.bits.shape[:-2])) if q.bits.ndim > 2 else 1
    n_w = lead * q.k * q.n
    weight_bits = (q.k_b * 1 + q.k_s * 4) / q.k
    index_bits = lead * q.k / n_w
    additional = lead * (2 * q.n + q.k_b + 2 * q.k_s) * SCALE_BITS / n_w
    return BitsReport(weight_bits, index_bits, additional,
                      weight_bits + index_bits + additional, n_w)


def model_bits(qparams: Tree) -> Dict[str, Any]:
    """Aggregate over every QLinear; also count exempt fp params."""
    reports: List[BitsReport] = []
    exempt = 0
    q_weights = 0
    bit_sum = 0.0

    def visit(leaf):
        nonlocal exempt, q_weights, bit_sum
        if isinstance(leaf, QLinear):
            r = qlinear_bits(leaf)
            reports.append(r)
            q_weights += r.n_weights
            bit_sum += r.total_bits * r.n_weights
        elif hasattr(leaf, "size"):
            exempt += int(leaf.size)
        return leaf

    jax.tree.map(visit, qparams, is_leaf=lambda x: isinstance(x, QLinear))
    avg = bit_sum / max(1, q_weights)
    return {
        "avg_bits_per_quantized_weight": avg,
        "quantized_weights": q_weights,
        "exempt_params": exempt,
        "exempt_fraction": exempt / max(1, exempt + q_weights),
        "per_layer": reports,
        "checkpoint_gbytes": (bit_sum / 8 + exempt * 2) / 1e9,
    }


def paper_closed_form(k: int = 4096, n: int = 4096, ratio: float = 0.2
                      ) -> BitsReport:
    """The Appendix-A worked example (4096×4096, 20% salient)."""
    k_s = int(k * ratio)
    k_b = k - k_s
    weight_bits = (k_b * 1 + k_s * 4) / k
    index_bits = k / (k * n)
    additional = (2 * n + k_b + 2 * k_s) * SCALE_BITS / (k * n)
    return BitsReport(weight_bits, index_bits, additional,
                      weight_bits + index_bits + additional, k * n)
