"""Binarization with learnable scaling factors (paper §3.1, §3.3, Eq. 9).

Analytic XNOR-Net initialization:  α_w = ‖w‖₁ / n_w  per output channel.
PTQ1.61 form (Eq. 9):

    W_q' = (α_r1 × α_r2) ∘ (α_s · sign(W))

with α_s, α_r1 per *output* channel (N,) and α_r2 per *input* channel
(K,) — the rank-1 (α_r1 × α_r2) field captures angular bias that a pure
row scale cannot (RBNN/LRQuant motivation).  α_r1/α_r2 initialize at 1 so
the init exactly matches the analytic binarization; the block-wise
optimizer (repro.core.blockwise) then learns all three.

Weight convention is (K=in, N=out) throughout — the paper's (n×m) rows
are our columns.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def analytic_alpha(w: jax.Array) -> jax.Array:
    """α per output channel: mean |w| over the input dim. w: (..., K, N)."""
    return jnp.mean(jnp.abs(w.astype(jnp.float32)), axis=-2)


def binarize_init(w: jax.Array) -> Dict[str, jax.Array]:
    """Signs + scale init for a (…, K, N) weight slice."""
    return {
        "sign": jnp.where(w >= 0, 1.0, -1.0).astype(jnp.bfloat16),
        "alpha_s": analytic_alpha(w),                       # (..., N)
        "alpha_r1": jnp.ones(w.shape[:-2] + (w.shape[-1],), jnp.float32),
        "alpha_r2": jnp.ones(w.shape[:-2] + (w.shape[-2],), jnp.float32),
    }


def dequant_binary(sign: jax.Array, alpha_s: jax.Array, alpha_r1: jax.Array,
                   alpha_r2: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """Eq. 9: (α_r1 × α_r2) ∘ (α_s sign(W)) -> (..., K, N)."""
    col = (alpha_s * alpha_r1)[..., None, :]      # (..., 1, N)
    row = alpha_r2[..., :, None]                  # (..., K, 1)
    return (sign.astype(jnp.float32) * col * row).astype(dtype)


def binarize_rtn(w: jax.Array) -> jax.Array:
    """Plain analytic binarization (the paper's Table-3 first row)."""
    b = binarize_init(w)
    return dequant_binary(b["sign"], b["alpha_s"], b["alpha_r1"], b["alpha_r2"],
                          dtype=w.dtype)
