"""Selection of quantizable weight leaves.

PTQ1.61 (like PB-LLM/BiLLM) quantizes the *linear projection matrices* of
every block; embeddings, lm_head, norms, biases, MoE routers, recurrence
gate vectors and conv kernels stay fp16 (DESIGN.md §4) and are counted by
the bit-accounting report.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Tuple

import jax

Tree = Any

# final-key names of quantizable linears across all block kinds
QUANT_NAMES = frozenset({
    "wq", "wk", "wv", "wo",          # attention (incl. cross)
    "wg", "wu", "wd",                # MLP and MoE experts
    "w_x", "w_gate", "w_out",        # RG-LRU projections
    "w_q", "w_k", "w_v",             # mLSTM projections
    "w_gates", "w_up", "w_down",     # sLSTM projections
})


def _leaf_name(path) -> str:
    last = path[-1]
    return getattr(last, "key", getattr(last, "name", str(last)))


def is_quantizable(path, leaf, min_dim: int) -> bool:
    if not hasattr(leaf, "shape") or len(leaf.shape) < 2:
        return False
    if _leaf_name(path) not in QUANT_NAMES:
        return False
    k, n = leaf.shape[-2], leaf.shape[-1]
    return k >= min_dim and n >= 16


def map_quantizable(tree: Tree, fn: Callable[[Tuple, Any], Any],
                    min_dim: int = 64, is_leaf=None) -> Tree:
    """Replace each quantizable leaf by fn(path, leaf); others unchanged."""
    def visit(path, leaf):
        if is_quantizable(path, leaf, min_dim):
            return fn(path, leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, tree, is_leaf=is_leaf)


def quantizable_paths(tree: Tree, min_dim: int = 64) -> List[str]:
    out = []
    def visit(path, leaf):
        if is_quantizable(path, leaf, min_dim):
            out.append(jax.tree_util.keystr(path))
        return leaf
    jax.tree_util.tree_map_with_path(visit, tree)
    return out
