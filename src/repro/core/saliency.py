"""Structured 1-D salient-channel mask (paper §3.2).

The layer quantization error `E = |X (W_qᵀ − Wᵀ)|` obeys (Eq. 4)

    E ≤ Σᵢ |xᵢ| · Σⱼ |w_{i,j}^q − w_{i,j}|

so the *input-activation channel magnitude* |xᵢ| controls the upper bound.
We therefore rank input channels by calibration statistics s_i = E[|x_i|]
and keep the top ρ (= 20%) of K at 4-bit; the rest binarize.

The mask is one bit per *input channel* (K bits total, ≈0.0002 bits per
weight for a 4096×4096 layer — Appendix A).  We additionally derive the
salient-first channel permutation from the mask (stable order), which is
storage-free, so the packed layout is contiguous: `[0:k_s) int4 |
[k_s:K) binary` (TPU adaptation, DESIGN.md §3).

A Hessian-diagonal proxy ranking (OWQ/BiLLM-style, `hessian=True`) is
included for the Appendix-B comparison benchmarks.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def activation_saliency(x_absmean: jax.Array) -> jax.Array:
    """Identity hook — saliency *is* the channel-wise E[|x|] statistic."""
    return x_absmean


def hessian_saliency(x_sqmean: jax.Array, w: jax.Array) -> jax.Array:
    """OWQ-style proxy: diag(H) = 2 E[x²]; rank by sensitivity
    s_i = diag(H)_i * ||w_i||² (per input channel i of w (K,N))."""
    return 2.0 * x_sqmean * jnp.sum(jnp.square(w.astype(jnp.float32)), axis=-1)


def round_salient(k: int, ratio: float, multiple: int) -> int:
    """Salient channel count: ratio·K rounded to a pack/shard-friendly
    multiple, clamped to [multiple, K - multiple]."""
    k_s = int(round(ratio * k / multiple)) * multiple
    k_s = max(multiple, min(k_s, k - multiple))
    return k_s


def structured_mask(saliency: jax.Array, ratio: float,
                    multiple: int) -> Tuple[jax.Array, jax.Array, int]:
    """Rank channels, return (mask bool (K,), perm (K,) salient-first, k_s).

    `perm` is the stable salient-first ordering: salient channels in their
    original relative order, then non-salient — fully derivable from the
    1-bit mask, so it costs no extra storage.
    """
    k = saliency.shape[-1]
    k_s = round_salient(k, ratio, multiple)
    # top-k_s channels by saliency
    _, top_idx = jax.lax.top_k(saliency, k_s)
    mask = jnp.zeros((k,), bool).at[top_idx].set(True)
    order = jnp.argsort(~mask, stable=True)  # salient (False<True) first
    return mask, order.astype(jnp.int32), k_s
