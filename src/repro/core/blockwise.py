"""Block-wise scaling-factor optimization (paper §3.3, Eq. 5–7).

Two-branch objective per transformer block (following CBQ):

    argmin_{α_s, α_r1, α_r2}  E(F(X, W),  F(X_q, W_q'))     # branch 1:
                            + E(F(X_q, W), F(X_q, W_q'))     # error propagation
                                                             # branch 2: same-
                                                             # input distortion
with  E(f1, f2) = ‖f1 − f2‖₂² + D_NLC(f1, f2)                (Eq. 5)
      D_NLC     = −log( cosine_similarity(f1, f2) )          (Eq. 6)

X is the full-precision calibration stream, X_q the quantized stream
(outputs of previously-quantized blocks).  Only the three scale fields of
each QLinear are learnable; signs and int4 codes stay fixed.  AdamW,
zero weight decay, lr 5e-4 (α_s) / 1e-3 (α_r1, α_r2) per the paper.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.qlinear import QLinear, QuantConfig, scale_params, with_scales
from repro.optim.adamw import AdamW

Tree = Any


def nlc(f1: jax.Array, f2: jax.Array) -> jax.Array:
    """Negative-log cosine similarity over the feature dim (Eq. 6)."""
    a = f1.astype(jnp.float32)
    b = f2.astype(jnp.float32)
    num = jnp.sum(a * b, axis=-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + 1e-8
    c = jnp.clip(num / den, 1e-3, 1.0)
    return -jnp.mean(jnp.log(c))


def metric(f1: jax.Array, f2: jax.Array, cosine: bool = True) -> jax.Array:
    """Eq. 5 distance: MSE + NLC."""
    m = jnp.mean(jnp.square(f1.astype(jnp.float32) - f2.astype(jnp.float32)))
    return m + (nlc(f1, f2) if cosine else 0.0)


def _is_q(x) -> bool:
    return isinstance(x, QLinear)


def extract_scales(q_block: Tree) -> Dict[str, Tree]:
    out = {}
    def visit(path, leaf):
        if _is_q(leaf):
            out[jax.tree_util.keystr(path)] = scale_params(leaf)
        return leaf
    jax.tree_util.tree_map_with_path(visit, q_block, is_leaf=_is_q)
    return out


def inject_scales(q_block: Tree, scales: Dict[str, Tree]) -> Tree:
    def visit(path, leaf):
        if _is_q(leaf):
            return with_scales(leaf, scales[jax.tree_util.keystr(path)])
        return leaf
    return jax.tree_util.tree_map_with_path(visit, q_block, is_leaf=_is_q)


def optimize_block_scales(
        block_fn: Callable[[Tree, jax.Array], jax.Array],
        fp_block: Tree, q_block: Tree,
        x_fp: List[jax.Array], x_q: List[jax.Array],
        qcfg: QuantConfig) -> Tree:
    """Learn the α's of every QLinear in `q_block` (Eq. 7).

    block_fn(params, x) -> block output (the embedding function F).
    x_fp / x_q: per-calibration-batch input streams.
    """
    scales0 = extract_scales(q_block)
    if not scales0 or not qcfg.learn_scales:
        return q_block

    # fixed targets per batch: F(X,W) and F(X_q,W)
    targets = [(block_fn(fp_block, xf), block_fn(fp_block, xq))
               for xf, xq in zip(x_fp, x_q)]

    opt = AdamW(lr=qcfg.lr, weight_decay=0.0)
    opt_state = opt.init(scales0)
    r_gain = qcfg.lr_r / qcfg.lr

    def loss_fn(scales, xq, y1, y2):
        qb = inject_scales(q_block, scales)
        yq = block_fn(qb, xq)
        return (metric(y1, yq, qcfg.cosine_loss) +
                metric(y2, yq, qcfg.cosine_loss))

    @jax.jit
    def step(scales, opt_state, xq, y1, y2):
        loss, grads = jax.value_and_grad(loss_fn)(scales, xq, y1, y2)
        # per-group lr: angular factors train faster (paper: 5e-4 / 1e-3)
        grads = {k: {"alpha_s": g["alpha_s"],
                     "alpha_r1": g["alpha_r1"] * r_gain,
                     "alpha_r2": g["alpha_r2"] * r_gain}
                 for k, g in grads.items()}
        scales, opt_state = opt.update(grads, opt_state, scales)
        return scales, opt_state, loss

    scales = scales0
    last = None
    for _ in range(qcfg.steps):
        for xq, (y1, y2) in zip(x_q, targets):
            scales, opt_state, last = step(scales, opt_state, xq, y1, y2)
    return inject_scales(q_block, scales)
