"""4-bit quantization of salient input channels (paper §3.2, App. A).

Per *input channel* asymmetric min/max quantization: one fp16 scale and
one zero-point per salient channel (the App.-A accounting's
"0.2·4096 zero-points").  q = clamp(round(w/s) + z, 0, 15).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

QMAX = 15


def quantize_int4(w: jax.Array) -> Dict[str, jax.Array]:
    """w: (..., k_s, N) salient slice -> {q (uint8 codes), s, z per channel}."""
    wf = w.astype(jnp.float32)
    wmin = jnp.min(wf, axis=-1)                      # (..., k_s)
    wmax = jnp.max(wf, axis=-1)
    scale = jnp.maximum((wmax - wmin) / QMAX, 1e-8)
    zero = jnp.clip(jnp.round(-wmin / scale), 0, QMAX)
    q = jnp.clip(jnp.round(wf / scale[..., None]) + zero[..., None], 0, QMAX)
    return {"q": q.astype(jnp.uint8), "s": scale, "z": zero}


def dequant_int4(q: jax.Array, s: jax.Array, z: jax.Array,
                 dtype=jnp.bfloat16) -> jax.Array:
    return ((q.astype(jnp.float32) - z[..., None]) * s[..., None]).astype(dtype)


def fake_quant_int4(w: jax.Array) -> jax.Array:
    d = quantize_int4(w)
    return dequant_int4(d["q"], d["s"], d["z"], dtype=w.dtype)
