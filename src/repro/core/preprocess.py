"""Quantization preprocessing via restorative LoRA (paper §3.4, App. D).

Pretrained checkpoints have *scattered* salient weights, which per-channel
(row-wise) scale assignment handles badly.  Before quantization we:

  1. build an *initial quantized* model Q0(W) (data-free PTQ1.61 init);
  2. attach rank-r LoRA adapters to every quantizable linear and train
     them so Q0(W) + BA recovers the pretrained model's behaviour on
     pretraining-distribution data (LM loss; the paper uses RedPajama —
     here the synthetic corpus, DESIGN.md §8);
  3. merge the learned low-rank compensation into the **full-precision**
     weights: W' = W + BA.

Because BA is low-rank, the compensation concentrates salient mass into a
few rows — the "row-wise pattern" of paper Fig. 4 — which then quantizes
better under any per-channel PTQ method (paper Fig. 5 shows the same merge
also lifts GPTQ/PB-LLM/BiLLM; benchmarks/fig5_preprocess.py reproduces).

Unlike post-quantization PEFT (QLoRA et al.) nothing extra ships at
inference: the adapters are merged *before* quantization (paper App. D.4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.pipeline import quantize_params_data_free
from repro.core.qlinear import QLinear, QuantConfig
from repro.core.select import map_quantizable
from repro.models import model as M
from repro.models.common import Parallel
from repro.optim.adamw import AdamW

Tree = Any


@dataclasses.dataclass(frozen=True)
class PreprocessConfig:
    rank: int = 32                # paper: rank 32
    steps: int = 10_000           # paper: 10K steps (tests use ~50)
    lr: float = 1e-4
    lora_alpha: float = 16.0
    seed: int = 7


def _is_q(x) -> bool:
    return isinstance(x, QLinear)


def init_lora(params: Tree, pcfg: PreprocessConfig,
              min_dim: int = 64) -> Dict[str, Tree]:
    """{path: {a: (..., r, N), b: (..., K, r)}} for quantizable leaves."""
    key = jax.random.PRNGKey(pcfg.seed)
    lora: Dict[str, Tree] = {}

    def visit(path, w):
        nonlocal key
        key, sub = jax.random.split(key)
        lead = w.shape[:-2]
        k, n = w.shape[-2:]
        r = min(pcfg.rank, k // 2, n // 2)
        a = 0.01 * jax.random.normal(sub, lead + (r, n), jnp.float32)
        b = jnp.zeros(lead + (k, r), jnp.float32)
        lora[jax.tree_util.keystr(path)] = {"a": a, "b": b}
        return w

    map_quantizable(params, visit, min_dim=min_dim)
    return lora


def merge_lora(base: Tree, lora: Dict[str, Tree], scale: float,
               min_dim: int = 64, dense_from=None) -> Tree:
    """base leaf (or its fake-quant dense) + scale·B@A per quantizable path."""
    def visit(path, w):
        key = jax.tree_util.keystr(path)
        if key not in lora:
            return w
        ab = lora[key]
        delta = scale * jnp.einsum("...kr,...rn->...kn", ab["b"], ab["a"])
        wd = dense_from(key, w) if dense_from is not None else w
        return (wd.astype(jnp.float32) + delta).astype(w.dtype)
    return map_quantizable(base, visit, min_dim=min_dim)


def restorative_lora(cfg: ArchConfig, par: Parallel, params: Tree,
                     batches: List[Dict[str, jax.Array]],
                     qcfg: QuantConfig,
                     pcfg: PreprocessConfig = PreprocessConfig(),
                     min_dim: int = 64,
                     log: Optional[Callable[[str], None]] = None) -> Tree:
    """Return the *preprocessed full-precision* checkpoint W' = W + BA."""
    _log = log or (lambda s: None)
    # 1) initial quantized model, frozen as fake-quant dense tensors
    q0 = quantize_params_data_free(
        params, dataclasses.replace(qcfg, learn_scales=False), min_dim=min_dim)
    q0_dense = jax.tree.map(
        lambda leaf: leaf.to_dense() if _is_q(leaf) else leaf, q0,
        is_leaf=_is_q)

    lora = init_lora(params, pcfg, min_dim=min_dim)
    if not lora:
        return params
    scale = pcfg.lora_alpha / pcfg.rank
    opt = AdamW(lr=pcfg.lr, weight_decay=0.0)
    opt_state = opt.init(lora)

    def loss_fn(lora, batch):
        eff = merge_lora(q0_dense, lora, scale, min_dim=min_dim)
        return M.forward_loss(cfg, par, eff, batch)

    @jax.jit
    def step(lora, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(lora, batch)
        lora, opt_state = opt.update(grads, opt_state, lora)
        return lora, opt_state, loss

    n = len(batches)
    for i in range(pcfg.steps):
        lora, opt_state, loss = step(lora, opt_state, batches[i % n])
        if i % max(1, pcfg.steps // 10) == 0:
            _log(f"restorative-lora step {i}: loss {float(loss):.4f}")

    # 3) merge the restorative compensation into the FP weights
    return merge_lora(params, lora, scale, min_dim=min_dim)
