"""QLinear — the packed PTQ1.61 weight pytree and its forward.

Storage layout (per (K, N) linear, K = input dim):
  perm        (K,)  int32   salient-first stable channel permutation
                            (derivable from the 1-bit mask; stored for O(1)
                            use — accounted as the mask's K bits)
  w4          (k_s/2, N) u8 packed int4 codes of salient channels
  s4, z4      (k_s,) f32    per-salient-channel scale / zero-point
  bits        (k_b/8, N) u8 packed signs of binarized channels
  alpha_s     (N,) f32      analytic/learned row scale (Eq. 2)
  alpha_r1    (N,) f32      learned angular factor, output side (Eq. 9)
  alpha_r2    (k_b,) f32    learned angular factor, input side (Eq. 9)

Forward (math identical to Eq. 9 + int4 dequant):
  y = x[.., perm_s] @ W4deq  +  ((x[.., perm_b] * α_r2) @ sign) * (α_s·α_r1)

The packed arrays are PRE-PERMUTED: ``quantize_linear`` folds the
salient-first permutation into ``w4``/``bits`` row order at quantization
time, so the forward needs exactly ONE activation gather (``x[.., perm]``)
and no weight-side reordering — ``__matmul_permuted__`` skips even that
when the caller already holds salient-first activations (the kernel
dispatcher and the N-fused group path below).

Leading stack dims (scan layers L, experts E) are supported on all array
fields; static metadata lives in pytree aux so stacked QLinears slice
cleanly under `jax.lax.scan`.

The XLA path below dequantizes on the fly (what the dry-run lowers); on
TPU the Pallas kernels in ``repro.kernels`` implement the same contraction
streaming packed bytes HBM→VMEM (``use_kernel=True``).

Decode N-fusion: :class:`QLinearGroup` stores several same-input
projections (QKV, gate+up) as ONE quantized matrix concatenated along N,
sharing a single permutation / int4 scale set / α_r2 — each transformer
block then issues 2 packed matmuls instead of 5 and gathers the
activation once per group instead of once per projection.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import binarize, int4, pack, saliency as sal

Tree = Any


@dataclass(frozen=True)
class QuantConfig:
    """PTQ1.61 hyper-parameters (paper §4.1 defaults)."""

    ratio: float = 0.2            # salient input-channel fraction (Fig. 6)
    multiple: int = 128           # k_s rounding (pack & 16-way TP divisibility)
    steps: int = 20               # block-wise optimization epochs
    lr: float = 5e-4              # AdamW lr for scales (paper: 5e-4 / 1e-3)
    lr_r: float = 1e-3            # lr for angular factors
    cosine_loss: bool = True      # D_NLC term (Eq. 5-6); ablation toggle
    learn_scales: bool = True     # Table-3 "Learnable Scalar" toggle
    use_mask: bool = True         # Table-3 "Structured Mask" toggle
    hessian_mask: bool = False    # OWQ-style ranking (App. B comparison)
    preprocess: bool = False      # Table-3 "Preprocess" toggle (restorative LoRA)
    use_kernel: bool = False      # dispatch Pallas kernels instead of XLA dequant


@jax.tree_util.register_pytree_node_class
@dataclass
class QLinear:
    perm: jax.Array
    w4: jax.Array
    s4: jax.Array
    z4: jax.Array
    bits: jax.Array
    alpha_s: jax.Array
    alpha_r1: jax.Array
    alpha_r2: jax.Array
    k_s: int = dataclasses.field(metadata={"static": True})
    k: int = dataclasses.field(metadata={"static": True})
    n: int = dataclasses.field(metadata={"static": True})
    use_kernel: bool = dataclasses.field(default=False, metadata={"static": True})

    _FIELDS = ("perm", "w4", "s4", "z4", "bits", "alpha_s", "alpha_r1",
               "alpha_r2")

    def tree_flatten(self):
        children = tuple(getattr(self, f) for f in self._FIELDS)
        aux = (self.k_s, self.k, self.n, self.use_kernel)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # ---- helpers -----------------------------------------------------
    @property
    def k_b(self) -> int:
        return self.k - self.k_s

    def dequant_salient(self, dtype=jnp.bfloat16) -> jax.Array:
        q = pack.unpack_nibbles(self.w4, axis=-2, dtype=jnp.float32)
        return int4.dequant_int4(q.astype(jnp.uint8), self.s4, self.z4, dtype)

    def dequant_binary(self, dtype=jnp.bfloat16) -> jax.Array:
        sign = pack.unpack_bits(self.bits, axis=-2, dtype=jnp.float32)
        return binarize.dequant_binary(sign, self.alpha_s, self.alpha_r1,
                                       self.alpha_r2, dtype)

    def to_dense(self, dtype=jnp.bfloat16) -> jax.Array:
        """Reconstruct the (…, K, N) fake-quant matrix in original channel
        order (testing / fake-quant evaluation)."""
        wq = jnp.concatenate(
            [self.dequant_salient(dtype), self.dequant_binary(dtype)], axis=-2)
        inv = jnp.argsort(self.perm, axis=-1)
        if self.perm.ndim == 1:
            return wq[..., inv, :]
        return jnp.take_along_axis(wq, inv[..., :, None], axis=-2)

    # ---- forward ------------------------------------------------------
    def __matmul_x__(self, x: jax.Array) -> jax.Array:
        """x: (..., K) -> (..., N).  2-D weights only (stacked weights are
        sliced by scan before reaching here)."""
        if self.use_kernel:
            from repro.kernels import ops
            return ops.mixed_matmul(x, self)
        return self.__matmul_permuted__(jnp.take(x, self.perm, axis=-1))

    def __matmul_permuted__(self, xp: jax.Array) -> jax.Array:
        """Forward over ALREADY salient-first-permuted activations —
        the one-gather entry point shared by the XLA path, the kernel
        dispatcher's fallback, and fused-group callers."""
        xs, xb = xp[..., : self.k_s], xp[..., self.k_s:]
        y4 = jnp.einsum("...k,kn->...n", xs, self.dequant_salient(xp.dtype))
        sign = pack.unpack_bits(self.bits, axis=-2, dtype=xp.dtype)
        yb = jnp.einsum("...k,kn->...n", xb * self.alpha_r2.astype(xp.dtype),
                        sign)
        yb = yb * (self.alpha_s * self.alpha_r1).astype(xp.dtype)
        return y4 + yb

    def __expert_matmul__(self, x: jax.Array) -> jax.Array:
        """x: (E, C, K) with stacked per-expert weights (E, ...)."""
        xp = jnp.take_along_axis(x, self.perm[:, None, :], axis=-1)
        xs, xb = xp[..., : self.k_s], xp[..., self.k_s:]
        y4 = jnp.einsum("eck,ekn->ecn", xs, self.dequant_salient(x.dtype))
        sign = pack.unpack_bits(self.bits, axis=-2, dtype=x.dtype)
        yb = jnp.einsum("eck,ekn->ecn",
                        xb * self.alpha_r2[:, None, :].astype(x.dtype), sign)
        yb = yb * (self.alpha_s * self.alpha_r1)[:, None, :].astype(x.dtype)
        return y4 + yb

    # ---- storage ------------------------------------------------------
    def packed_bytes(self) -> int:
        tot = 0
        for f in self._FIELDS:
            a = getattr(self, f)
            tot += a.size * a.dtype.itemsize
        return tot


def quantize_linear(w: jax.Array, act_stat: Optional[jax.Array],
                    qcfg: QuantConfig) -> QLinear:
    """PTQ1.61 initial quantization of one (…, K, N) weight (no learning).

    act_stat: per-input-channel saliency statistic E[|x|] (K,) (or stacked).
    Without a mask (ablation), every channel binarizes (k_s=multiple is the
    floor, so we use k_s=0 semantics via an empty salient slice).
    """
    k, n = w.shape[-2], w.shape[-1]
    if act_stat is None:
        act_stat = jnp.mean(jnp.abs(w.astype(jnp.float32)), axis=-1)
    if qcfg.hessian_mask:
        stat = sal.hessian_saliency(jnp.square(act_stat), w)
    else:
        stat = act_stat

    def one(wm, sv):
        if qcfg.use_mask:
            _, perm, k_s = sal.structured_mask(sv, qcfg.ratio, qcfg.multiple)
        else:
            perm = jnp.arange(k, dtype=jnp.int32)
            k_s = 0
        wp = wm[perm]
        ws, wb = wp[:k_s], wp[k_s:]
        if k_s:
            q4 = int4.quantize_int4(ws)
            w4 = pack.pack_nibbles(q4["q"], axis=-2)
            s4, z4 = q4["s"], q4["z"]
        else:
            w4 = jnp.zeros((0, n), jnp.uint8)
            s4 = z4 = jnp.zeros((0,), jnp.float32)
        b = binarize.binarize_init(wb)
        bits = pack.pack_bits(b["sign"], axis=-2)
        return (perm, w4, s4, z4, bits, b["alpha_s"], b["alpha_r1"],
                b["alpha_r2"]), k_s

    if w.ndim == 2:
        (fields), k_s = one(w, stat)
    else:
        # stacked (layers and/or experts): flatten ALL leading dims, apply
        # per (K, N) slice, restore the leading shape on every field
        lead = w.shape[:-2]
        wf = w.reshape((-1,) + w.shape[-2:])
        sf = (stat.reshape((-1, stat.shape[-1]))
              if stat.ndim > 1 else None)
        outs = [one(wf[i], stat if sf is None else sf[i])
                for i in range(wf.shape[0])]
        k_s = outs[0][1]
        fields = tuple(
            jnp.stack([o[0][j] for o in outs]).reshape(
                lead + outs[0][0][j].shape)
            for j in range(8))
    return QLinear(*fields, k_s=k_s, k=k, n=n, use_kernel=qcfg.use_kernel)


# ---------------------------------------------------------------------------
# N-fused projection groups (decode fast path)
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclass
class QLinearGroup:
    """Several same-input projections fused along N into one weight.

    ``inner`` is either a plain (…, K, ΣN_i) array (exact fp fusion —
    concatenation changes no math) or a :class:`QLinear` quantized over
    the CONCATENATED weight, so every member shares one salient-first
    permutation, one (s4, z4) int4 scale set and one α_r2 — the
    structural requirement that lets the fused forward gather the
    activation once and issue one packed matmul for the whole group.

    ``splits`` records each member's output width; :meth:`split_out`
    recovers per-member outputs and :meth:`members` rebuilds unfused
    per-member views (the equivalence oracle: slicing the packed arrays
    along N is exact because pack layouts keep N contiguous).
    """

    inner: Any
    splits: Tuple[int, ...] = dataclasses.field(metadata={"static": True})

    def tree_flatten(self):
        return (self.inner,), (self.splits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    # ---- shape helpers ------------------------------------------------
    @property
    def n(self) -> int:
        return sum(self.splits)

    @property
    def k(self) -> int:
        if isinstance(self.inner, QLinear):
            return self.inner.k
        return self.inner.shape[-2]

    # ---- forward ------------------------------------------------------
    def __matmul_x__(self, x: jax.Array) -> jax.Array:
        """Fused forward: x (..., K) -> (..., ΣN_i) in one matmul (and,
        for quantized inners, one activation gather)."""
        if hasattr(self.inner, "__matmul_x__"):
            return self.inner.__matmul_x__(x)
        return jnp.einsum("...k,kn->...n", x, self.inner.astype(x.dtype))

    def __expert_matmul__(self, x: jax.Array) -> jax.Array:
        """Fused per-expert forward: x (E, C, K) with stacked (E, …)
        member weights -> (E, C, ΣN_i) — one batched matmul (and, when
        quantized, one per-expert activation gather) for the whole
        group, the MoE twin of the decode QKV/gate-up fusion."""
        if hasattr(self.inner, "__expert_matmul__"):
            return self.inner.__expert_matmul__(x)
        return jnp.einsum("eck,ekn->ecn", x, self.inner.astype(x.dtype))

    def split_out(self, y: jax.Array) -> Tuple[jax.Array, ...]:
        """Slice a fused output back into per-member outputs."""
        return tuple(pack.split_cols(y, self.splits))

    def forward_split(self, x: jax.Array) -> Tuple[jax.Array, ...]:
        return self.split_out(self.__matmul_x__(x))

    # ---- oracle -------------------------------------------------------
    def members(self) -> Tuple[Any, ...]:
        """Per-member unfused views over the SAME quantized (or fp)
        data — the bit-equivalence oracle for the fused path."""
        if not isinstance(self.inner, QLinear):
            return tuple(pack.split_cols(self.inner, self.splits))
        q = self.inner
        out = []
        for w4, bits, a_s, a_r1, ni in zip(
                pack.split_cols(q.w4, self.splits),
                pack.split_cols(q.bits, self.splits),
                pack.split_cols(q.alpha_s, self.splits),
                pack.split_cols(q.alpha_r1, self.splits),
                self.splits):
            out.append(QLinear(q.perm, w4, q.s4, q.z4, bits, a_s, a_r1,
                               q.alpha_r2, k_s=q.k_s, k=q.k, n=ni,
                               use_kernel=q.use_kernel))
        return tuple(out)

    def packed_bytes(self) -> int:
        if isinstance(self.inner, QLinear):
            return self.inner.packed_bytes()
        return self.inner.size * self.inner.dtype.itemsize


def quantize_linear_group(ws, act_stat: Optional[jax.Array],
                          qcfg: QuantConfig) -> QLinearGroup:
    """PTQ1.61-quantize a list of same-K weights as ONE fused layout.

    The members are concatenated along N before masking/quantization, so
    the salient-channel mask (driven by the SHARED input activations)
    and all K-side parameters are common to the group — exactly the
    pre-permuted packed layout the fused decode kernel streams.
    """
    ks = {w.shape[-2] for w in ws}
    if len(ks) != 1:
        raise ValueError(f"fused members must share K, got {sorted(ks)}")
    splits = tuple(int(w.shape[-1]) for w in ws)
    fused = jnp.concatenate(list(ws), axis=-1)
    return QLinearGroup(quantize_linear(fused, act_stat, qcfg), splits)


def scale_params(q: QLinear) -> Tree:
    """The learnable subset for block-wise optimization (Eq. 7 argmin)."""
    return {"alpha_s": q.alpha_s, "alpha_r1": q.alpha_r1,
            "alpha_r2": q.alpha_r2}


def with_scales(q: QLinear, s: Tree) -> QLinear:
    return dataclasses.replace(q, alpha_s=s["alpha_s"],
                               alpha_r1=s["alpha_r1"], alpha_r2=s["alpha_r2"])


def field_axes(prefix: Tuple, in_ax, out_ax):
    """Logical axes per QLinear field, given the original weight's
    (prefix…, in_ax, out_ax) annotation.  Consumed by
    ``repro.distributed.sharding`` to build PartitionSpec QLinears."""
    return {
        "perm": prefix + (in_ax,),
        "w4": prefix + (in_ax, out_ax),
        "s4": prefix + (in_ax,),
        "z4": prefix + (in_ax,),
        "bits": prefix + (in_ax, out_ax),
        "alpha_s": prefix + (out_ax,),
        "alpha_r1": prefix + (out_ax,),
        "alpha_r2": prefix + (in_ax,),
    }
