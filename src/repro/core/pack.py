"""Bit-exact packing for sub-2-bit storage.

* 1-bit weights: 8 signs per uint8 byte along the input (K) dimension —
  bit j of byte i is the sign of channel k = 8*i + j (1 = +1, 0 = -1).
* 4-bit weights: two nibbles per uint8 byte along K — low nibble is
  channel 2*i, high nibble 2*i+1.

Both layouts keep the *output* (N) dimension contiguous, which is the
layout the Pallas kernels stream (HBM→VMEM transfers of packed bytes,
unpack in VMEM).  All functions are shape-polymorphic in trailing dims so
stacked-layer (L, K, N) and per-expert (E, K, N) weights pack the same way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_BIT_SHIFTS = tuple(range(8))


def pack_bits(signs: jax.Array, axis: int = -2) -> jax.Array:
    """Pack ±1 (or bool) signs along `axis` (must be a multiple of 8).

    signs: (..., K, N) float/int/bool -> (..., K//8, N) uint8.
    """
    axis = axis % signs.ndim
    k = signs.shape[axis]
    assert k % 8 == 0, f"K={k} not a multiple of 8"
    bits = (signs > 0).astype(jnp.uint8)
    shp = signs.shape[:axis] + (k // 8, 8) + signs.shape[axis + 1:]
    bits = bits.reshape(shp)
    weights = jnp.asarray([1 << s for s in _BIT_SHIFTS], jnp.uint8)
    bshape = (1,) * (axis + 1) + (8,) + (1,) * (signs.ndim - axis - 1)
    return jnp.sum(bits * weights.reshape(bshape), axis=axis + 1,
                   dtype=jnp.uint8)


def unpack_bits(packed: jax.Array, axis: int = -2,
                dtype=jnp.bfloat16) -> jax.Array:
    """uint8 (..., K//8, N) -> ±1 in `dtype` (..., K, N)."""
    axis = axis % packed.ndim
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bshape = (1,) * (axis + 1) + (8,) + (1,) * (packed.ndim - axis - 1)
    bits = (jnp.expand_dims(packed, axis + 1) >> shifts.reshape(bshape)) & 1
    out_shape = packed.shape[:axis] + (packed.shape[axis] * 8,) + packed.shape[axis + 1:]
    bits = bits.reshape(out_shape)
    return (bits.astype(dtype) * 2 - 1)


def pack_nibbles(q: jax.Array, axis: int = -2) -> jax.Array:
    """Pack uint4 values (0..15) along `axis` (multiple of 2) into uint8."""
    axis = axis % q.ndim
    k = q.shape[axis]
    assert k % 2 == 0, f"K={k} not a multiple of 2"
    q = q.astype(jnp.uint8)
    shp = q.shape[:axis] + (k // 2, 2) + q.shape[axis + 1:]
    q = q.reshape(shp)
    lo = jax.lax.index_in_dim(q, 0, axis + 1, keepdims=False)
    hi = jax.lax.index_in_dim(q, 1, axis + 1, keepdims=False)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jax.Array, axis: int = -2,
                   dtype=jnp.bfloat16) -> jax.Array:
    """uint8 (..., K//2, N) -> values 0..15 in `dtype` (..., K, N)."""
    axis = axis % packed.ndim
    lo = packed & 0xF
    hi = packed >> 4
    stacked = jnp.stack([lo, hi], axis=axis + 1)
    out_shape = packed.shape[:axis] + (packed.shape[axis] * 2,) + packed.shape[axis + 1:]
    return stacked.reshape(out_shape).astype(dtype)


def split_cols(a: jax.Array, splits) -> list:
    """Split the trailing (N) axis into per-member slices.

    Because both packed layouts keep N contiguous (K is the packed
    axis), slicing ``w4``/``bits`` columns out of an N-fused matrix is
    bit-exact — no unpack/repack round trip.  Works on any rank (scale
    vectors (…, N) and packed matrices (…, K/8, N) alike).
    """
    idx = np.cumsum(np.asarray(splits))[:-1]
    return jnp.split(a, [int(i) for i in idx], axis=-1)


def packed_nbytes(k_salient: int, k_binary: int, n: int) -> int:
    """Storage bytes for one quantized (K, N) matrix (weights only)."""
    return (k_binary // 8) * n + (k_salient // 2) * n
