"""Calibration-time activation statistics capture.

The structured mask (paper §3.2) ranks *input channels of each linear* by
E[|x_i|] over the calibration set.  We capture those statistics exactly —
per linear, at its real input (post-norm, post-residual, per-expert) — by
swapping every quantizable weight for a recording wrapper and running the
model **eagerly** over calibration batches.  The wrapper computes the same
matmul, so the forward is unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.select import map_quantizable

Tree = Any


class StatsWeight:
    """Drop-in weight that records per-input-channel E[|x|] and E[x²],
    optionally the full input Gram matrix Σ xᵀx (GPTQ/BiLLM Hessian
    H = 2·Σ xᵀx) and a capped sample of raw input rows (AWQ grid search)."""

    def __init__(self, w, collect_hessian: bool = False,
                 sample_rows: int = 0):
        self.w = w
        self.sum_abs = None
        self.sum_sq = None
        self.count = 0
        self.collect_hessian = collect_hessian
        self.h = None
        self.sample_rows = sample_rows
        self.samples = []

    def _record(self, x, axes):
        xa = jnp.abs(x.astype(jnp.float32))
        s_abs = np.asarray(jnp.sum(xa, axis=axes))
        s_sq = np.asarray(jnp.sum(jnp.square(x.astype(jnp.float32)), axis=axes))
        n = int(np.prod([x.shape[a] for a in axes]))
        if self.sum_abs is None:
            self.sum_abs, self.sum_sq = s_abs, s_sq
        else:
            self.sum_abs = self.sum_abs + s_abs
            self.sum_sq = self.sum_sq + s_sq
        self.count += n
        if self.collect_hessian and x.ndim >= 2:
            flat = np.asarray(x.astype(jnp.float32)).reshape(-1, x.shape[-1])
            g = flat.T @ flat
            self.h = g if self.h is None else self.h + g
        if self.sample_rows and sum(s.shape[0] for s in self.samples) < self.sample_rows:
            flat = np.asarray(x.astype(jnp.float32)).reshape(-1, x.shape[-1])
            self.samples.append(flat[: self.sample_rows])

    @property
    def hessian(self) -> np.ndarray:
        return 2.0 * self.h / max(1, self.count)

    @property
    def x_sample(self) -> np.ndarray:
        return np.concatenate(self.samples, 0) if self.samples else None

    def __matmul_x__(self, x):
        self._record(x, tuple(range(x.ndim - 1)))
        return jnp.einsum("...k,kn->...n", x, self.w.astype(x.dtype))

    def __expert_matmul__(self, x):
        # per-expert channel stats: reduce over the capacity dim only
        self._record(x, (1,))
        return jnp.einsum("eck,ekn->ecn", x, self.w.astype(x.dtype))

    @property
    def absmean(self) -> np.ndarray:
        return self.sum_abs / max(1, self.count)

    @property
    def sqmean(self) -> np.ndarray:
        return self.sum_sq / max(1, self.count)


def collect_stats(forward, params: Tree, batches: List[Dict[str, jax.Array]],
                  min_dim: int = 64) -> Dict[str, np.ndarray]:
    """Run `forward(wrapped_params, batch)` eagerly per batch; return
    {keystr(path): absmean (…,K)} for every quantizable leaf."""
    w = collect_wrappers(forward, params, batches, min_dim=min_dim)
    return {k: np.asarray(sw.absmean) for k, sw in w.items()
            if sw.sum_abs is not None}


def collect_wrappers(forward, params: Tree,
                     batches: List[Dict[str, jax.Array]], *,
                     min_dim: int = 64, collect_hessian: bool = False,
                     sample_rows: int = 0) -> Dict[str, StatsWeight]:
    """Full-detail variant: returns the wrappers themselves (absmean,
    sqmean, Hessian, input samples) per quantizable path."""
    wrappers: Dict[str, StatsWeight] = {}

    def wrap(path, leaf):
        sw = StatsWeight(leaf, collect_hessian=collect_hessian,
                         sample_rows=sample_rows)
        wrappers[jax.tree_util.keystr(path)] = sw
        return sw

    wrapped = map_quantizable(params, wrap, min_dim=min_dim)
    for batch in batches:
        forward(wrapped, batch)
    return wrappers
