"""End-to-end PTQ1.61 quantization driver (paper Fig. 2).

Sequential block-by-block protocol with error propagation:

  1. (optional) quantization preprocessing — restorative LoRA merge
     (repro.core.preprocess, paper §3.4);
  2. embed the calibration segments -> FP stream X and quantized stream X_q;
  3. per block, in depth order:
       a. capture per-linear input-channel statistics on the X_q stream
          (what the deployed layer will actually see),
       b. structured mask + int4/binary initial quantization (§3.2),
       c. block-wise scale optimization (§3.3, Eq. 7),
       d. propagate both streams through FP / quantized block;
  4. restack per-layer QLinears into the scan layout.

`quantize_params_data_free` is the fast path (|w|-magnitude saliency, no
optimization) used for serving-shape generation and smoke tests of the
non-dense families; the full driver is exercised on the tiny LM subjects
(benchmarks/table1, table3).
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import blockwise
from repro.core.calibrate import collect_stats
from repro.core.qlinear import (QLinear, QLinearGroup, QuantConfig,
                                quantize_linear)
from repro.core.select import map_quantizable
from repro.models import model as M
from repro.models import transformer as T
from repro.models.common import Parallel

Tree = Any


def tree_slice(tree: Tree, i: int) -> Tree:
    return jax.tree.map(lambda a: a[i], tree)


def tree_stack(trees: List[Tree]) -> Tree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _is_group(leaf) -> bool:
    return isinstance(leaf, QLinearGroup)


def _quantize_group_inners(tree: Tree, qcfg: QuantConfig,
                           min_dim: int) -> Tree:
    """Quantize the fused fp matrix inside each QLinearGroup (one shared
    mask/permutation per group — the fused packed layout)."""
    import dataclasses

    def visit(leaf):
        if _is_group(leaf) and isinstance(leaf.inner, jax.Array) \
                and leaf.k >= min_dim:
            return dataclasses.replace(
                leaf, inner=quantize_linear(leaf.inner, None, qcfg))
        return leaf

    return jax.tree.map(visit, tree, is_leaf=_is_group)


def quantize_params_data_free(params: Tree, qcfg: QuantConfig,
                              min_dim: int = 64,
                              fuse: bool = False) -> Tree:
    """Mask from |w| magnitude, analytic scales, no learning.  Works for
    every architecture (incl. stacked layer/expert weights).

    ``fuse=True`` first concatenates QKV and gate+up along N
    (:func:`repro.models.transformer.fuse_params_for_decode`) and then
    quantizes each fused matrix as ONE PTQ1.61 layout — shared
    permutation, int4 scales and α_r2 — producing the packed layouts the
    decode fast path streams with 2 kernel calls per block instead of 5.
    """
    if fuse:
        params = T.fuse_params_for_decode(params)

    def q(_, w):
        return quantize_linear(w, None, qcfg)
    params = map_quantizable(params, q, min_dim=min_dim, is_leaf=_is_group)
    if fuse:
        params = _quantize_group_inners(params, qcfg, min_dim)
    return params


def _block_forward(cfg: ArchConfig, par: Parallel, kind: str):
    def fn(block_params, x):
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        y, _ = T.block_full(cfg, par, kind, block_params, x, positions,
                            causal=True)
        return y
    return fn


def quantize_model_ptq161(
        cfg: ArchConfig, par: Parallel, params: Tree,
        calib_batches: List[Dict[str, jax.Array]], qcfg: QuantConfig,
        min_dim: int = 64, log: Optional[Callable[[str], None]] = None,
) -> Tree:
    """Full PTQ1.61 over a decoder-only model.  Returns params with every
    quantizable leaf replaced by a learned QLinear."""
    assert not cfg.enc_dec, "calibrated PTQ driver targets decoder-only LMs"
    t0 = time.time()
    _log = log or (lambda s: None)

    # calibration streams
    x_fp = [M.embed_tokens(cfg, params, b["tokens"]) for b in calib_batches]
    x_q = [x for x in x_fp]

    qstages: List[List[List[Tree]]] = []  # [stage][pattern_pos][layer]
    for si, stage in enumerate(cfg.stages):
        qstages.append([[] for _ in stage.pattern])
        for layer in range(stage.repeats):
            for pi, kind in enumerate(stage.pattern):
                fp_block = tree_slice(params["stages"][si][pi], layer)
                fwd = _block_forward(cfg, par, kind)

                # (a) input-channel stats on the quantized stream
                stats = collect_stats(lambda p, b: fwd(p, b), fp_block,
                                      x_q, min_dim=min_dim)

                # (b) initial quantization
                def qinit(path, w):
                    key = jax.tree_util.keystr(path)
                    s = stats.get(key)
                    s = None if s is None else jnp.asarray(s)
                    return quantize_linear(w, s, qcfg)
                q_block = map_quantizable(fp_block, qinit, min_dim=min_dim)

                # (c) scale learning (Eq. 7)
                q_block = blockwise.optimize_block_scales(
                    fwd, fp_block, q_block, x_fp, x_q, qcfg)

                # (d) propagate (block output + residual handled inside
                # block_full, which already returns x + f(x))
                fwd_j = jax.jit(fwd)
                x_fp = [fwd_j(fp_block, x) for x in x_fp]
                x_q = [fwd_j(q_block, x) for x in x_q]

                qstages[si][pi].append(q_block)
                _log(f"stage{si} layer{layer} kind={kind} "
                     f"({time.time()-t0:.1f}s)")

    qparams = dict(params)
    qparams["stages"] = [tuple(tree_stack(qstages[si][pi])
                               for pi in range(len(stage.pattern)))
                         for si, stage in enumerate(cfg.stages)]
    return qparams
