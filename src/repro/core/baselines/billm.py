"""BiLLM (Huang et al., 2024): Hessian-guided residual binarization.

Three weight groups per layer, binarized separately:
  * salient (top fraction by Hessian sensitivity s_i = h_ii·w², taken
    column-structured like the reference implementation's row selection):
    RESIDUAL binarization — binarize, then binarize the residual again
    (effectively ~2 bits of expressiveness on salient weights);
  * non-salient split by an optimal |w| threshold ("bell-shape" split)
    into concentrated / sparse groups, each with its own analytic α.

Equivalent storage (App. A): 1-bit codes + group masks ≈ 2.1 b/w — above
2 bits despite the "1-bit" branding, which is PTQ1.61's critique.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _binarize(w: jax.Array, mask: jax.Array):
    """α over masked entries (per output channel), sign reconstruction."""
    cnt = jnp.maximum(jnp.sum(mask, axis=0, keepdims=True), 1)
    alpha = jnp.sum(jnp.where(mask, jnp.abs(w), 0.0), axis=0,
                    keepdims=True) / cnt
    return jnp.where(w >= 0, alpha, -alpha)


def billm_quantize(w: jax.Array, hessian_diag: Optional[np.ndarray],
                   salient_frac: float = 0.1, n_split: int = 16) -> jax.Array:
    """Fake-quant w (K, N)."""
    wf = w.astype(jnp.float32)
    k, n = wf.shape
    if hessian_diag is None:
        sens = jnp.mean(jnp.square(wf), axis=1)
    else:
        sens = jnp.asarray(hessian_diag, jnp.float32) * jnp.mean(
            jnp.square(wf), axis=1)
    k_sal = max(1, int(round(salient_frac * k)))
    _, sal_idx = jax.lax.top_k(sens, k_sal)
    sal_rows = jnp.zeros((k,), bool).at[sal_idx].set(True)[:, None]

    # salient: residual binarization (two passes)
    b1 = _binarize(wf, sal_rows)
    b2 = _binarize(wf - b1, sal_rows)
    sal = b1 + b2

    # non-salient: optimal magnitude split into two groups
    nonsal = ~sal_rows & jnp.ones_like(wf, bool)
    absw = jnp.abs(jnp.where(nonsal, wf, jnp.nan))
    lo = jnp.nanmin(absw)
    hi = jnp.nanmax(absw)
    best_err, best = jnp.inf, None
    for i in range(1, n_split):
        t = lo + (hi - lo) * i / n_split
        g_hi = nonsal & (jnp.abs(wf) >= t)
        g_lo = nonsal & (jnp.abs(wf) < t)
        rec = jnp.where(g_hi, _binarize(wf, g_hi), _binarize(wf, g_lo))
        err = float(jnp.sum(jnp.where(nonsal, (rec - wf) ** 2, 0.0)))
        if err < best_err:
            best_err, best = err, rec

    return jnp.where(sal_rows, sal, best).astype(w.dtype)


def bits_per_weight() -> float:
    # paper App. A: weight 1.0 + additional 0.1 + unstructured group mask 1.0
    return 2.1
