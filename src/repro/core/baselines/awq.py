"""AWQ (Lin et al., 2023): activation-aware weight scaling + RTN.

Per-input-channel scales s = stat^α lifted onto the weights before
quantization and divided back after; α grid-searched to minimize the
layer-output MSE on calibration samples.  No mask, no learned factors —
the paper's App.-B comparison point.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines.rtn import rtn_quantize


def awq_quantize(w: jax.Array, act_absmean: Optional[np.ndarray], bits: int,
                 x_sample: Optional[np.ndarray] = None,
                 grid: int = 20) -> jax.Array:
    """Fake-quant w (K, N) with the best activation-aware scaling."""
    if act_absmean is None:
        return rtn_quantize(w, bits)
    stat = jnp.asarray(act_absmean, jnp.float32)
    stat = stat / (jnp.mean(stat) + 1e-8) + 1e-4
    if x_sample is not None and x_sample.size:
        x = jnp.asarray(x_sample, jnp.float32)
    else:
        x = None
    wf = w.astype(jnp.float32)

    best = (jnp.inf, rtn_quantize(w, bits))
    for g in range(grid):
        alpha = g / grid
        s = jnp.power(stat, alpha)[:, None]       # (K,1)
        wq = rtn_quantize(wf * s, bits).astype(jnp.float32) / s
        if x is None:
            err = jnp.mean(jnp.square(wq - wf))
        else:
            err = jnp.mean(jnp.square(x @ wq - x @ wf))
        err = float(err)
        if err < best[0]:
            best = (err, wq.astype(w.dtype))
    return best[1]


def bits_per_weight(bits: int, k: int, n: int) -> float:
    # b-bit codes + fp16 scale/zero per output channel + fp16 s per input ch.
    return bits + (2 * n + k) * 16 / (k * n)
