"""Round-to-nearest b-bit quantization (per output channel, asymmetric).

The weakest baseline in the paper's tables (2-bit RTN ≈ collapse); also
the primitive reused by PB-LLM (8-bit salient) and AWQ (post-scaling RTN).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rtn_quantize(w: jax.Array, bits: int) -> jax.Array:
    """Fake-quant w (…, K, N) with per-output-channel (N) min/max grid."""
    wf = w.astype(jnp.float32)
    qmax = 2 ** bits - 1
    wmin = jnp.min(wf, axis=-2, keepdims=True)
    wmax = jnp.max(wf, axis=-2, keepdims=True)
    scale = jnp.maximum((wmax - wmin) / qmax, 1e-8)
    zero = jnp.clip(jnp.round(-wmin / scale), 0, qmax)
    q = jnp.clip(jnp.round(wf / scale) + zero, 0, qmax)
    return ((q - zero) * scale).astype(w.dtype)


def bits_per_weight(bits: int, k: int, n: int) -> float:
    """b-bit codes + fp16 scale/zero per output channel."""
    return bits + (2 * n * 16) / (k * n)
