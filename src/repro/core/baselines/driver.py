"""Sequential calibrated driver for the baseline quantizers.

Mirrors the PTQ1.61 pipeline (block-by-block, stats on the propagated
quantized stream) but each quantizable leaf becomes a FAKE-QUANT dense
tensor — exactly how the paper evaluates the baselines (their unstructured
masks aren't servable sub-2-bit, which is the paper's point).

Methods: rtn-{2,3,4,8} | gptq-{2,3,4} | awq-2 | pbllm | billm.
"""
from __future__ import annotations

import functools
import re
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.baselines import awq, billm, gptq, pbllm, rtn
from repro.core.calibrate import collect_wrappers
from repro.core.pipeline import _block_forward, tree_slice, tree_stack
from repro.core.select import map_quantizable
from repro.models import model as M
from repro.models.common import Parallel

Tree = Any


def parse_method(method: str):
    m = re.fullmatch(r"(rtn|gptq|awq)-(\d+)", method)
    if m:
        return m.group(1), int(m.group(2))
    if method in ("pbllm", "billm"):
        return method, None
    raise ValueError(f"unknown baseline {method!r}")


def method_bits(method: str, k: int = 4096, n: int = 4096) -> float:
    kind, b = parse_method(method)
    if kind == "rtn":
        return rtn.bits_per_weight(b, k, n)
    if kind == "gptq":
        return gptq.bits_per_weight(b, k, n)
    if kind == "awq":
        return awq.bits_per_weight(b, k, n)
    if kind == "pbllm":
        return pbllm.bits_per_weight(k=k, n=n)
    return billm.bits_per_weight()


def quantize_model_baseline(
        cfg: ArchConfig, par: Parallel, params: Tree,
        calib_batches: List[Dict[str, jax.Array]], method: str,
        min_dim: int = 64,
        log: Optional[Callable[[str], None]] = None) -> Tree:
    kind, b = parse_method(method)
    _log = log or (lambda s: None)
    needs_h = kind in ("gptq", "billm")
    needs_x = kind == "awq"

    x_q = [M.embed_tokens(cfg, params, batch["tokens"])
           for batch in calib_batches]

    qstages: List[List[List[Tree]]] = []
    for si, stage in enumerate(cfg.stages):
        qstages.append([[] for _ in stage.pattern])
        for layer in range(stage.repeats):
            for pi, bk in enumerate(stage.pattern):
                fp_block = tree_slice(params["stages"][si][pi], layer)
                fwd = _block_forward(cfg, par, bk)
                wrappers = collect_wrappers(
                    lambda p, x: fwd(p, x), fp_block, x_q, min_dim=min_dim,
                    collect_hessian=needs_h, sample_rows=256 if needs_x else 0)

                def qfn(path, w):
                    key = jax.tree_util.keystr(path)
                    sw = wrappers.get(key)
                    if w.ndim > 2:   # stacked experts: apply per slice
                        return jnp.stack([
                            _quant_one(kind, b, w[i],
                                       None if sw is None else sw, i)
                            for i in range(w.shape[0])])
                    return _quant_one(kind, b, w, sw, None)

                q_block = map_quantizable(fp_block, qfn, min_dim=min_dim)
                fwd_j = jax.jit(fwd)
                x_q = [fwd_j(q_block, x) for x in x_q]
                qstages[si][pi].append(q_block)
                _log(f"[{method}] stage{si} layer{layer} kind={bk}")

    qparams = dict(params)
    qparams["stages"] = [tuple(tree_stack(qstages[si][pi])
                               for pi in range(len(st.pattern)))
                         for si, st in enumerate(cfg.stages)]
    return qparams


def _quant_one(kind: str, b: Optional[int], w, sw, expert: Optional[int]):
    absmean = None if sw is None or sw.sum_abs is None else sw.absmean
    if absmean is not None and expert is not None:
        absmean = absmean[expert]
    if kind == "rtn":
        return rtn.rtn_quantize(w, b)
    if kind == "gptq":
        h = None if sw is None or sw.h is None else sw.hessian
        if h is not None and expert is not None:
            h = None   # per-expert Hessian not tracked; fall back
        return gptq.gptq_quantize(w, h, b)
    if kind == "awq":
        xs = None if sw is None else sw.x_sample
        return awq.awq_quantize(w, absmean, b, x_sample=xs)
    if kind == "pbllm":
        return pbllm.pbllm_quantize(w)
    if kind == "billm":
        hd = None
        if sw is not None and sw.h is not None:
            hd = np.diag(sw.hessian)
        return billm.billm_quantize(w, hd)
    raise ValueError(kind)
