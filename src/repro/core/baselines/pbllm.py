"""PB-LLM (Shang et al., 2023): partially-binarized LLM.

Top-10% |w|-magnitude weights (UNSTRUCTURED — scattered positions) kept at
8-bit RTN; the remaining 90% binarized with per-output-channel analytic α
computed over the non-salient weights only.

The unstructured mask costs a full extra 1 bit/weight (uncompressible
bitmap, App. A):  b = 0.1·8 + 0.9·1 + 1 = 2.7 b/w — the paper's central
criticism that PTQ1.61's structured mask removes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pbllm_quantize(w: jax.Array, salient_frac: float = 0.1,
                   salient_bits: int = 8) -> jax.Array:
    """Fake-quant w (K, N)."""
    wf = w.astype(jnp.float32)
    k, n = wf.shape
    n_sal = max(1, int(round(salient_frac * k * n)))
    thresh = jnp.sort(jnp.abs(wf).reshape(-1))[-n_sal]
    mask = jnp.abs(wf) >= thresh                    # unstructured (K,N)

    # salient: 8-bit RTN on the salient values (per output channel grid)
    qmax = 2 ** salient_bits - 1
    big = jnp.where(mask, wf, 0.0)
    wmax = jnp.max(jnp.abs(big), axis=0, keepdims=True)
    scale = jnp.maximum(2 * wmax / qmax, 1e-8)
    q = jnp.clip(jnp.round(wf / scale) + (qmax + 1) // 2, 0, qmax)
    sal = (q - (qmax + 1) // 2) * scale

    # non-salient: binarize, α over non-salient entries only
    cnt = jnp.maximum(jnp.sum(~mask, axis=0, keepdims=True), 1)
    alpha = jnp.sum(jnp.where(mask, 0.0, jnp.abs(wf)), axis=0,
                    keepdims=True) / cnt
    bin_ = jnp.where(wf >= 0, alpha, -alpha)

    return jnp.where(mask, sal, bin_).astype(w.dtype)


def bits_per_weight(salient_frac: float = 0.1, salient_bits: int = 8,
                    k: int = 4096, n: int = 4096) -> float:
    return (salient_frac * salient_bits + (1 - salient_frac) * 1.0
            + 1.0                       # unstructured mask bitmap
            + 2 * n * 16 / (k * n))     # scales
