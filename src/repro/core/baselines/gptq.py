"""GPTQ (Frantar et al., 2022) in JAX.

Column-by-column quantization over the input dimension with second-order
error compensation: after quantizing input channel k of every output row,
the residual error is propagated into not-yet-quantized channels using
the inverse-Hessian Cholesky factors.

    H = 2 Σ xᵀx + λI          (λ = percdamp · mean diag)
    Hinv = Cholesky(H⁻¹)ᵀ      (upper triangular)
    for k in 0..K-1:
        q_k   = quant(w_k)
        err_k = (w_k − q_k) / Hinv[k,k]
        W[:, k+1:] −= err_k · Hinv[k, k+1:]

Runs as a `lax.fori_loop` over K with in-place buffer updates — O(K²·N)
like the reference CUDA implementation (blocked variant unnecessary at
our scales).  Weight convention (K, N): we operate on Wᵀ rows = output
channels, matching the paper's row-wise grid.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _grid(w: jax.Array, bits: int):
    """Per-output-row symmetric-ish min/max grid (N,) scales/zeros."""
    qmax = 2 ** bits - 1
    wmin = jnp.min(w, axis=-1, keepdims=True)    # w here is (N, K)
    wmax = jnp.max(w, axis=-1, keepdims=True)
    scale = jnp.maximum((wmax - wmin) / qmax, 1e-8)
    zero = jnp.clip(jnp.round(-wmin / scale), 0, qmax)
    return scale, zero, qmax


def gptq_quantize(w: jax.Array, hessian: Optional[np.ndarray], bits: int,
                  percdamp: float = 0.01) -> jax.Array:
    """Fake-quant w (K, N) given the layer's input Gram/Hessian (K, K)."""
    k, n = w.shape
    wt = w.astype(jnp.float32).T                 # (N, K) rows=outputs
    if hessian is None:
        h = jnp.eye(k, dtype=jnp.float32)
    else:
        h = jnp.asarray(hessian, jnp.float32)
    # dead channels (H_ii = 0) -> freeze via identity damping
    diag = jnp.diag(h)
    dead = diag <= 0
    h = h + jnp.diag(jnp.where(dead, 1.0, 0.0))
    damp = percdamp * jnp.mean(jnp.where(dead, 0.0, diag))
    h = h + damp * jnp.eye(k, dtype=jnp.float32)
    hinv = jnp.linalg.cholesky(jnp.linalg.inv(h), upper=True)  # (K, K)

    scale, zero, qmax = _grid(wt, bits)

    def body(i, carry):
        wbuf, qbuf = carry
        col = wbuf[:, i]
        d = hinv[i, i]
        q = jnp.clip(jnp.round(col / scale[:, 0]) + zero[:, 0], 0, qmax)
        dq = (q - zero[:, 0]) * scale[:, 0]
        err = (col - dq) / d
        # propagate into remaining columns (mask j <= i)
        row = hinv[i]                              # (K,)
        mask = (jnp.arange(k) > i).astype(jnp.float32)
        wbuf = wbuf - jnp.outer(err, row * mask)
        qbuf = qbuf.at[:, i].set(dq)
        return wbuf, qbuf

    _, qt = jax.lax.fori_loop(0, k, body, (wt, jnp.zeros_like(wt)))
    return qt.T.astype(w.dtype)


def bits_per_weight(bits: int, k: int, n: int) -> float:
    return bits + (2 * n * 16) / (k * n)
