"""Request scheduler: weighted priority classes, preemption, deadlines.

The scheduler owns the waiting queues and the *policy* decisions; the
engine owns the slots, caches and device steps and asks the scheduler:

  * ``next_admissible(...)`` — which queued request (if any) may start
    now, given free pages.  Admission picks across per-class FCFS
    queues by **weighted deficit round robin with an aging term**:
    every admission round each backlogged class accrues credit equal to
    its weight, the served class is charged the round's total, and the
    class whose (deficit + aging · head-wait) score is highest admits
    its head.  Long-run service shares are proportional to the weights,
    while the aging term bounds any class's wait under sustained
    higher-priority load — a low-weight head's score grows without
    bound until it wins a round (the anti-starvation guarantee the
    starvation test pins).  Within a class admission is strict FCFS
    (no reordering past the class head).  Across classes admission is
    work-conserving: the round walks classes in score order and admits
    the first head that fits — a top-scored head that does not fit is
    skipped WITHOUT being charged, so it keeps first claim on pages
    the moment they free while lower-scored classes fill the gap.
    Deficits are clamped to ±2× the round total, so a class blocked
    for a long stretch cannot wind up unbounded credit and burst past
    its weight share once capacity frees.
  * ``choose_victim(...)`` — which running request to preempt when the
    page pool is exhausted mid-decode.  Victim selection is
    class-aware: candidates are narrowed to the *lowest-weight* class
    present, then the configured policy picks within it — ``"newest"``
    (most recently admitted — least completed work lost, vLLM-style,
    the default) or ``"oldest"``.  The victim's pages are freed and the
    request is re-queued at the *front of its class queue* (it becomes
    that class's longest-waiting request and is re-admitted first, so
    preemption cannot starve it).
  * ``expire(...)`` — drop queued requests whose deadline passed while
    waiting.  Running requests are never killed by a deadline; only
    admission is gated (a request that started is cheapest to finish).

Requests are duck-typed: anything with ``rid`` / ``deadline_t`` /
``admit_seq`` attributes (see ``repro.runtime.engine.Request``); an
optional ``priority`` attribute names the class (default
``"standard"``).  The scheduler stamps ``enqueue_t`` (its clock) on
every enqueue — the aging term reads it off the class head.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional

from repro.runtime.paged_cache import pages_for_tokens

PREEMPT_POLICIES = ("newest", "oldest")
DEFAULT_CLASS = "standard"
DEFAULT_CLASS_WEIGHTS: Mapping[str, float] = {
    "realtime": 8.0, "standard": 4.0, "batch": 1.0}


@dataclass(frozen=True)
class SchedulerConfig:
    preempt_policy: str = "newest"
    # weighted-deficit admission across per-class FCFS queues; weights
    # are service shares (realtime gets 8/13 of admissions under full
    # backlog), aging_rate converts head wait seconds into score so no
    # class waits forever (score units per second)
    class_weights: Mapping[str, float] = field(
        default_factory=lambda: dict(DEFAULT_CLASS_WEIGHTS))
    aging_rate: float = 1.0

    def __post_init__(self):
        if self.preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(f"preempt_policy {self.preempt_policy!r} not in "
                             f"{PREEMPT_POLICIES}")
        if DEFAULT_CLASS not in self.class_weights:
            raise ValueError(f"class_weights must include the default "
                             f"class {DEFAULT_CLASS!r}")
        if any(w <= 0 for w in self.class_weights.values()):
            raise ValueError("class weights must be positive")


class Scheduler:
    def __init__(self, cfg: SchedulerConfig = SchedulerConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        # per-class FCFS queues + deficit counters, iterated in the
        # (deterministic) class_weights declaration order
        self._queues: Dict[str, List] = {c: [] for c in cfg.class_weights}
        self._deficit: Dict[str, float] = {c: 0.0 for c in cfg.class_weights}
        self._admit_seq = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def queue_depth(self) -> int:
        return len(self)

    def has_class(self, name: str) -> bool:
        return name in self._queues

    def weight_of(self, req) -> float:
        return self.cfg.class_weights.get(
            getattr(req, "priority", DEFAULT_CLASS),
            self.cfg.class_weights[DEFAULT_CLASS])

    def _class_of(self, req) -> str:
        cls = getattr(req, "priority", DEFAULT_CLASS)
        if cls not in self._queues:
            raise ValueError(f"unknown priority class {cls!r}; configured: "
                             f"{sorted(self._queues)}")
        return cls

    def enqueue(self, req, front: bool = False) -> None:
        cls = self._class_of(req)
        # front re-enqueues are preemption victims: they KEEP their
        # original stamp so the aging term accumulates across
        # admit→preempt cycles instead of resetting each round
        if not (front and getattr(req, "enqueue_t", None) is not None):
            try:
                req.enqueue_t = self.clock()
            except AttributeError:  # read-only duck types: aging treats
                pass                # a missing stamp as zero wait
        if front:
            self._queues[cls].insert(0, req)
        else:
            self._queues[cls].append(req)

    def remove(self, rid: int):
        """Remove and return a queued request by rid (cancellation), or
        None when it is not queued."""
        for q in self._queues.values():
            for i, r in enumerate(q):
                if r.rid == rid:
                    return q.pop(i)
        return None

    def expire(self) -> List:
        """Remove and return queued requests whose deadline has passed.

        Only never-admitted requests (admit_seq == 0) expire: a
        preempted request waiting for re-admission has already been paid
        for (see the running-requests rule above) and keeps its place."""
        now = self.clock()
        dead = []
        for cls, q in self._queues.items():
            gone = [r for r in q
                    if getattr(r, "deadline_t", None) is not None
                    and r.deadline_t <= now
                    and getattr(r, "admit_seq", 0) == 0]
            if gone:
                ids = {id(r) for r in gone}
                self._queues[cls] = [r for r in q if id(r) not in ids]
                dead.extend(gone)
        return dead

    # ------------------------------------------------------------------
    def _score(self, cls: str, now: float) -> float:
        head = self._queues[cls][0]
        wait = max(0.0, now - getattr(head, "enqueue_t", now))
        return self._deficit[cls] + self.cfg.aging_rate * wait

    def next_admissible(self, free_pages: Optional[int], page_size: int,
                        shared_pages: Optional[Callable[[object], int]]
                        = None) -> Optional[object]:
        """Pop and return the winning class's FCFS head if it fits, else
        None.

        ``free_pages=None`` means the backend has no page budget
        (contiguous slots reserve ``max_seq`` up front) — the head always
        fits.  For the paged backend the head needs pages for its whole
        prompt *plus the first decode token* (the engine writes it in the
        same tick the request is admitted, after the growth pass already
        ran) minus any pages ``shared_pages(head)`` says a prefix-cache
        attach will cover; later decode pages are allocated lazily,
        block by block.  The round walks classes in score order and
        admits the FIRST head that fits (work-conserving): a blocked
        top-scored head is skipped uncharged — its score keeps leading,
        so it claims pages the moment they free, and the deficit clamp
        plus its unbounded aging term mean it is delayed, never starved
        and never owed an unbounded service burst.
        """
        backlogged = [c for c in self._queues if self._queues[c]]
        if not backlogged:
            return None
        now = self.clock()
        # DRR credit accrual, clamped against windup; empty classes
        # carry no credit (a class must not burst after an idle stretch)
        total = sum(self.cfg.class_weights[c] for c in backlogged)
        cap = 2.0 * sum(self.cfg.class_weights.values())
        for c in self._queues:
            if self._queues[c]:
                self._deficit[c] = min(
                    self._deficit[c] + self.cfg.class_weights[c], cap)
            else:
                self._deficit[c] = 0.0
        ranked = sorted(backlogged,
                        key=lambda c: (self._score(c, now),
                                       self.cfg.class_weights[c], c),
                        reverse=True)
        for best in ranked:
            head = self._queues[best][0]
            if free_pages is not None:
                need = pages_for_tokens(head.n_prompt_tokens() + 1,
                                        page_size)
                if shared_pages is not None:
                    need = max(1, need - int(shared_pages(head)))
                if need > free_pages:
                    continue            # skipped, not charged: keeps
                                        # first claim on freed pages
            self._queues[best].pop(0)
            self._deficit[best] = max(self._deficit[best] - total, -cap)
            self._admit_seq += 1
            head.admit_seq = self._admit_seq
            return head
        return None

    # ------------------------------------------------------------------
    def next_prefill_slot(self, prefilling: Dict[int, object]
                          ) -> Optional[int]:
        """Which in-progress chunked prefill advances this tick.

        ``prefilling`` maps slot -> request for every slot whose prompt
        is still being written chunk-by-chunk.  The pick mirrors the
        admission policy's spirit at chunk granularity: the
        highest-weight priority class present goes first (a realtime
        prompt's time-to-first-token is not held behind a batch
        prompt's), FCFS (admission order) within a class — so under a
        chunk budget of one per tick, concurrent prefills drain in
        class-then-arrival order rather than round-robin thrash."""
        cands = [(s, r) for s, r in prefilling.items() if r is not None]
        if not cands:
            return None
        slot, _ = min(cands, key=lambda sr: (-self.weight_of(sr[1]),
                                             getattr(sr[1], "admit_seq", 0),
                                             sr[0]))
        return slot

    # ------------------------------------------------------------------
    def choose_victim(self, running: Dict[int, object],
                      exclude: Optional[int] = None) -> Optional[int]:
        """Pick the slot to preempt when the pool is exhausted.

        ``running`` maps slot -> request; ``exclude`` protects the slot
        whose allocation triggered the preemption when other victims
        exist (preempting yourself frees no net capacity for you).
        Candidates narrow to the lowest-weight priority class present —
        batch work is evicted before realtime — then the configured
        newest/oldest policy picks within that class."""
        cands = [(s, r) for s, r in running.items() if r is not None]
        if exclude is not None and len(cands) > 1:
            cands = [(s, r) for s, r in cands if s != exclude]
        if not cands:
            return None
        wmin = min(self.weight_of(r) for _, r in cands)
        cands = [(s, r) for s, r in cands if self.weight_of(r) == wmin]
        newest = self.cfg.preempt_policy == "newest"
        key = lambda sr: sr[1].admit_seq
        slot, _ = (max if newest else min)(cands, key=key)
        return slot
