"""Request scheduler: FCFS admission, preemption policy, deadlines.

The scheduler owns the waiting queue and the *policy* decisions; the
engine owns the slots, caches and device steps and asks the scheduler:

  * ``next_admissible(...)`` — which queued request (if any) may start
    now, given free pages.  Strict FCFS: if the head of the queue does
    not fit, nothing is admitted (no reordering past the head, so a
    large request cannot starve behind a stream of small ones).
  * ``choose_victim(...)`` — which running request to preempt when the
    page pool is exhausted mid-decode.  The victim's pages are freed and
    the request is re-queued at the *front* (it becomes the
    longest-waiting request and is re-admitted first, so preemption
    cannot starve it).  Default victim policy is ``"newest"`` (most
    recently admitted — least completed work lost, vLLM-style);
    ``"oldest"`` is available for workloads where draining long-running
    requests first is preferable.
  * ``expire(...)`` — drop queued requests whose deadline passed while
    waiting.  Running requests are never killed by a deadline; only
    admission is gated (a request that started is cheapest to finish).

Requests are duck-typed: anything with ``rid`` / ``deadline_t`` /
``admit_seq`` attributes (see ``repro.runtime.engine.Request``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.runtime.paged_cache import pages_for_tokens

PREEMPT_POLICIES = ("newest", "oldest")


@dataclass(frozen=True)
class SchedulerConfig:
    preempt_policy: str = "newest"

    def __post_init__(self):
        if self.preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(f"preempt_policy {self.preempt_policy!r} not in "
                             f"{PREEMPT_POLICIES}")


class Scheduler:
    def __init__(self, cfg: SchedulerConfig = SchedulerConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self._queue: List = []
        self._admit_seq = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._queue)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def enqueue(self, req, front: bool = False) -> None:
        if front:
            self._queue.insert(0, req)
        else:
            self._queue.append(req)

    def expire(self) -> List:
        """Remove and return queued requests whose deadline has passed.

        Only never-admitted requests (admit_seq == 0) expire: a
        preempted request waiting for re-admission has already been paid
        for (see the running-requests rule above) and keeps its place."""
        now = self.clock()
        dead = [r for r in self._queue
                if getattr(r, "deadline_t", None) is not None
                and r.deadline_t <= now
                and getattr(r, "admit_seq", 0) == 0]
        if dead:
            gone = {id(r) for r in dead}
            self._queue = [r for r in self._queue if id(r) not in gone]
        return dead

    def next_admissible(self, free_pages: Optional[int],
                        page_size: int) -> Optional[object]:
        """Pop and return the FCFS head if it fits, else None.

        ``free_pages=None`` means the backend has no page budget
        (contiguous slots reserve ``max_seq`` up front) — the head always
        fits.  For the paged backend the head needs pages for its whole
        prompt *plus the first decode token* (the engine writes it in the
        same tick the request is admitted, after the growth pass already
        ran); later decode pages are allocated lazily, block by block.
        """
        if not self._queue:
            return None
        head = self._queue[0]
        if free_pages is not None:
            need = pages_for_tokens(head.n_prompt_tokens() + 1, page_size)
            if need > free_pages:
                return None
        self._queue.pop(0)
        self._admit_seq += 1
        head.admit_seq = self._admit_seq
        return head

    # ------------------------------------------------------------------
    def choose_victim(self, running: Dict[int, object],
                      exclude: Optional[int] = None) -> Optional[int]:
        """Pick the slot to preempt when the pool is exhausted.

        ``running`` maps slot -> request; ``exclude`` protects the slot
        whose allocation triggered the preemption when other victims
        exist (preempting yourself frees no net capacity for you)."""
        cands = [(s, r) for s, r in running.items() if r is not None]
        if exclude is not None and len(cands) > 1:
            cands = [(s, r) for s, r in cands if s != exclude]
        if not cands:
            return None
        newest = self.cfg.preempt_policy == "newest"
        key = lambda sr: sr[1].admit_seq
        slot, _ = (max if newest else min)(cands, key=key)
        return slot
