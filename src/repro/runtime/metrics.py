"""Engine metrics: latency, throughput and occupancy counters.

One :class:`EngineMetrics` instance rides along with an ``Engine``.  The
engine reports lifecycle events (submit / admit / first token / finish /
preempt / expire / cancel) and one gauge sample per decode tick;
``snapshot()`` reduces them to the serving numbers that matter — tokens/s,
time-to-first-token, inter-token latency (TBT), queue depth, page
utilization — and ``to_json()`` exports them for the benchmark harness
(``benchmarks/serving_bench.py``).

Now that the engine emits every token through the event bus the tick it
is sampled, **inter-token latency is observable per request**: every
``on_token`` after the first records the gap since the request's
previous token, and ``snapshot()`` reduces the gaps to p50/p95 both
overall and **per priority class** (``on_submit`` carries the class) —
the per-class TTFT/TBT split is what makes the weighted-deficit
scheduler's service shares visible in ``serving_bench``'s
mixed-priority rows.

The clock is injectable so tests can drive deterministic time.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, int(q * (len(ys) - 1) + 0.5))
    return ys[i]


@dataclass
class _ReqTimes:
    submit_t: float
    priority: str = "standard"
    admit_t: Optional[float] = None
    first_tok_t: Optional[float] = None
    last_tok_t: Optional[float] = None
    finish_t: Optional[float] = None
    tokens: int = 0
    tbt: List[float] = field(default_factory=list)  # inter-token gaps
    stall_seen: int = 0         # on_stall() count at the last token


class EngineMetrics:
    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self._req: Dict[int, _ReqTimes] = {}
        self._expired: set = set()
        self._cancelled: set = set()
        self._stalls = 0
        self.preemptions = 0
        self.expirations = 0
        self.cancellations = 0
        self.ticks = 0
        self.prefills = 0
        # chunked prefill: chunk calls / live tokens processed / tokens
        # skipped outright on prefix-cache hits (zero kernel calls)
        self.prefill_chunks = 0
        self.prefill_chunk_tokens = 0
        self.prefill_tokens_skipped = 0
        self._start_t: Optional[float] = None
        self._last_t: Optional[float] = None
        # per-tick gauge samples
        self.queue_depth: List[int] = []
        self.active_slots: List[int] = []
        self.page_util: List[float] = []
        # per-phase device-step wall times (engine reports blocked-on
        # -result durations around each jitted prefill / decode call)
        self.phase_times: Dict[str, List[float]] = {}

    # -- lifecycle events ----------------------------------------------
    def on_submit(self, rid: int, priority: str = "standard") -> None:
        now = self.clock()
        if self._start_t is None:
            self._start_t = now
        self._req[rid] = _ReqTimes(submit_t=now, priority=priority)

    def on_admit(self, rid: int) -> None:
        t = self._req[rid]
        if t.admit_t is None:          # keep the first admit (preemptions re-admit)
            t.admit_t = self.clock()
        self.prefills += 1

    def on_token(self, rid: int, n: int = 1) -> None:
        now = self.clock()
        self._last_t = now
        t = self._req[rid]
        if t.first_tok_t is None:
            t.first_tok_t = now
        elif t.last_tok_t is not None and t.stall_seen == self._stalls:
            # a gap spanning an on_stall() (XLA compile) is a one-time
            # warmup artifact, not inter-token latency — drop it so
            # tbt_p95 describes steady-state decode (TTFT still carries
            # the first compile, as it should)
            t.tbt.append(now - t.last_tok_t)
        t.last_tok_t = now
        t.stall_seen = self._stalls
        t.tokens += n

    def on_stall(self) -> None:
        """A one-time wall-clock stall (jit compile) happened: the next
        inter-token gap of every in-flight request is not decode
        latency and must not enter the TBT series."""
        self._stalls += 1

    def on_finish(self, rid: int) -> None:
        self._req[rid].finish_t = self.clock()

    def on_preempt(self, rid: int) -> None:
        self.preemptions += 1

    def on_expire(self, rid: int) -> None:
        self.expirations += 1
        self._expired.add(rid)      # never served: kept out of completed
                                    # counts and latency percentiles

    def on_cancel(self, rid: int) -> None:
        self.cancellations += 1
        self._cancelled.add(rid)    # partially served: tokens/TBT count,
                                    # completion/latency do not

    def on_prefill_chunk(self, n_tokens: int) -> None:
        """One chunked-prefill step processed ``n_tokens`` live prompt
        tokens (interleaved with decode in the same tick)."""
        self.prefill_chunks += 1
        self.prefill_chunk_tokens += n_tokens

    def on_prefill_skip(self, n_tokens: int) -> None:
        """``n_tokens`` of prompt were covered by prefix-cache pages and
        skipped the prefill compute entirely."""
        self.prefill_tokens_skipped += n_tokens

    def on_phase_time(self, phase: str, seconds: float) -> None:
        """Record one jitted step's wall time for ``phase``.  Decode runs
        at M=n_slots while prefill runs at the bucket length, so the two
        must be reported separately for the fused-projection /
        autotuned-kernel win to be visible.  The engine routes each
        compiled shape's first call to "<phase>_compile", keeping the
        base series pure steady-state."""
        self.phase_times.setdefault(phase, []).append(seconds)

    def on_tick(self, queue_depth: int, active_slots: int,
                page_util: Optional[float] = None) -> None:
        self.ticks += 1
        self._last_t = self.clock()
        self.queue_depth.append(queue_depth)
        self.active_slots.append(active_slots)
        if page_util is not None:
            self.page_util.append(page_util)

    # -- reduction ------------------------------------------------------
    @staticmethod
    def _latency_block(times: List["_ReqTimes"]) -> Dict:
        ttft = [t.first_tok_t - t.submit_t for t in times
                if t.first_tok_t is not None]
        tbt = [g for t in times for g in t.tbt]
        mean = lambda xs: sum(xs) / len(xs) if xs else 0.0
        return {
            "ttft_mean_s": mean(ttft),
            "ttft_p50_s": _percentile(ttft, 0.50),
            "ttft_p95_s": _percentile(ttft, 0.95),
            "tbt_mean_s": mean(tbt),
            "tbt_p50_s": _percentile(tbt, 0.50),
            "tbt_p95_s": _percentile(tbt, 0.95),
        }

    def snapshot(self) -> Dict:
        served = {rid: t for rid, t in self._req.items()
                  if rid not in self._expired}
        lat = [t.finish_t - t.submit_t for rid, t in served.items()
               if t.finish_t is not None and rid not in self._cancelled]
        tokens = sum(t.tokens for t in self._req.values())
        wall = ((self._last_t - self._start_t)
                if self._start_t is not None and self._last_t is not None
                else 0.0)
        mean = lambda xs: sum(xs) / len(xs) if xs else 0.0
        by_class: Dict[str, List[_ReqTimes]] = {}
        for rid, t in served.items():
            by_class.setdefault(t.priority, []).append(t)
        per_class = {
            cls: dict(
                requests=len(ts),
                completed=sum(1 for t in ts if t.finish_t is not None),
                generated_tokens=sum(t.tokens for t in ts),
                **self._latency_block(ts),
            ) for cls, ts in sorted(by_class.items())
        }
        return {
            "requests": len(self._req),
            "completed": sum(1 for rid, t in served.items()
                             if t.finish_t is not None
                             and rid not in self._cancelled),
            "generated_tokens": tokens,
            "wall_s": wall,
            "tokens_per_s": tokens / max(wall, 1e-9),
            **self._latency_block(list(served.values())),
            "latency_mean_s": mean(lat),
            "latency_p95_s": _percentile(lat, 0.95),
            "ticks": self.ticks,
            "prefills": self.prefills,
            "prefill_chunks": self.prefill_chunks,
            "prefill_chunk_tokens": self.prefill_chunk_tokens,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "preemptions": self.preemptions,
            "expirations": self.expirations,
            "cancellations": self.cancellations,
            "queue_depth_mean": mean(self.queue_depth),
            "queue_depth_max": max(self.queue_depth, default=0),
            "active_slots_mean": mean(self.active_slots),
            "page_util_mean": mean(self.page_util),
            "page_util_max": max(self.page_util, default=0.0),
            "per_class": per_class,
            "phase_step_s": {
                phase: {
                    "count": len(ts),
                    "total_s": sum(ts),
                    "mean_s": mean(ts),
                    "p50_s": _percentile(ts, 0.50),
                    "p95_s": _percentile(ts, 0.95),
                } for phase, ts in sorted(self.phase_times.items())
            },
        }

    def to_json(self, path: Optional[str] = None) -> str:
        s = json.dumps(self.snapshot(), indent=2, default=float)
        if path:
            with open(path, "w") as f:
                f.write(s)
        return s
