"""Typed engine events and the subscriber/queue bus.

The serving engine's ``tick()`` is reentrant: instead of buffering whole
requests inside ``run()``, every observable state change is published as
a typed event the moment it happens on the host —

  * :class:`TokenEvent` — one generated token (prefill's first sample or
    a decode-tick sample), before the request is anywhere near done.
    This is what makes streaming output and inter-token latency (TBT)
    measurable per tick.
  * :class:`FinishEvent` — terminal state for a request that produced
    output: ``reason`` is ``"max_new"`` (hit its token budget),
    ``"max_seq"`` (hit the context ceiling), ``"cancelled"``
    (:meth:`Engine.cancel`), or ``"empty"`` (``max_new<=0`` degenerate).
    Carries how many pool pages the release returned — cancellation
    frees pages in the same tick, and the event is the receipt.
  * :class:`PreemptEvent` — a running request was evicted to free pages;
    it is re-queued (front of its class queue) and will resume.
  * :class:`ExpireEvent` — a queued request's deadline passed before it
    was ever admitted; it is dropped without output.

Consumers attach either a callback (``subscribe``) or a drainable queue
(``queue()``) — the queue form is what ``launch/serve.py --stream`` uses
(drain between ticks, print tokens as they land).  Publishing happens
inside ``tick()`` on the engine's thread; callbacks must not re-enter
mutating engine APIs (``Engine.cancel`` called from a callback is
deferred to the end of the current tick for exactly this reason).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional, Union

FINISH_REASONS = ("max_new", "max_seq", "cancelled", "empty")


@dataclass(frozen=True)
class TokenEvent:
    rid: int
    token: int
    index: int          # position in the request's output stream (0-based)
    tick: int


@dataclass(frozen=True)
class FinishEvent:
    rid: int
    reason: str         # one of FINISH_REASONS
    n_tokens: int
    freed_pages: int
    tick: int


@dataclass(frozen=True)
class PreemptEvent:
    rid: int
    slot: int
    freed_pages: int
    tick: int


@dataclass(frozen=True)
class ExpireEvent:
    rid: int
    tick: int


Event = Union[TokenEvent, FinishEvent, PreemptEvent, ExpireEvent]


class EventBus:
    """Fan-out of engine events to callbacks and drainable queues."""

    def __init__(self):
        self._subs: List[Callable[[Event], None]] = []

    def subscribe(self, cb: Callable[[Event], None]) -> Callable:
        self._subs.append(cb)
        return cb

    def unsubscribe(self, cb: Callable) -> None:
        # equality, not identity: a deque's bound `q.append` is a fresh
        # object per attribute access, but compares equal — so
        # unsubscribe(q.append) really detaches a queue() subscriber
        self._subs = [s for s in self._subs if s != cb]

    def queue(self, maxlen: Optional[int] = None) -> Deque[Event]:
        """A new subscriber queue: every published event is appended.
        Drain with ``popleft()`` between ticks; a ``maxlen`` bounds
        memory for slow consumers (oldest events drop first)."""
        q: Deque[Event] = deque(maxlen=maxlen)
        self.subscribe(q.append)
        return q

    def publish(self, ev: Event) -> None:
        for cb in self._subs:
            cb(ev)
