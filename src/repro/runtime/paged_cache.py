"""Paged KV-cache block-pool allocator (host side), ref-counted + COW.

vLLM-style paging for the serving engine: the device KV cache is one
shared pool of fixed-size pages ``(num_pages, page_size, heads, head_dim)``
per attention layer stack, and each request owns a *block table* mapping
its token blocks ``t // page_size`` to pool pages.  Memory then scales
with the tokens actually resident instead of ``n_slots × max_seq``.

The layout is position-aligned: token ``t`` of a request always lives at
``(block_table[t // page_size], t % page_size)``, so the attention mask
can be derived from implied positions (``block·page_size + slot``) and no
per-slot position array has to be stored or cleared — a freed page can be
handed to the next request without touching device memory, because stale
slots are masked out by the new owner's shorter context.

Position alignment is also what makes **prefix sharing** a pure
allocator-layer feature: two requests whose prompts agree on the first
``k`` page-aligned chunks can point their first ``k`` block-table entries
at the *same* pool pages — the jitted decode step and the flash-decode
kernel are oblivious, they just follow the tables.  Three pieces
cooperate:

  * :class:`PagePool` pages carry a **refcount** — ``alloc`` returns
    pages at refcount 1, :meth:`PagePool.incref` adds holders,
    :meth:`PagePool.free` decrements and only returns a page to the free
    list when the count reaches zero (bumping its *generation* so stale
    registry entries can detect reuse).
  * :meth:`BlockTables.fork` attaches an existing page run to a slot's
    table **copy-on-write**: the pages are increfed and marked shared;
    prefill splices skip writing them (:meth:`BlockTables.writable_row`
    masks shared blocks to ``-1`` → the device scatter drops those
    writes), and any write landing in a shared block first triggers a
    COW copy (:meth:`BlockTables.ensure_for_position` allocates a
    private page and records a ``(src, dst)`` device copy the engine
    backend applies before the next decode).  In the prefix-sharing
    flow the copy NEVER fires by construction — only full pages
    strictly below the sharer's write frontier are attached, so
    ``cow_copies`` staying 0 is the invariant (serving_bench prints
    it) and the copy path is the enforced safety net.  Its real
    consumer is whole-sequence forks (parallel sampling / beam search,
    see ROADMAP), where a mid-generation attach puts the write
    frontier INSIDE a shared page.
  * :class:`PrefixCache` is the hash-keyed registry: page-aligned prompt
    chunks are keyed by a chained digest (chunk tokens folded into the
    parent chunk's key, so a match is always a *prefix* match) and map
    to the live pool page holding them.  Entries are validated against
    the pool's refcount/generation at lookup — a page freed and reused
    invalidates its entry lazily.  Only *full* pages strictly below the
    registrant's prompt length are registered: those pages are never
    written again by their owner (decode writes start at the prompt
    boundary), so sharers can attend them without a copy.

This module is pure host bookkeeping (free list + per-slot tables);
the device-side gather/scatter lives in ``repro.models.layers``
(:func:`attention_decode_paged`) and ``repro.models.transformer``
(``stage_copy_pages`` applies the COW page copies).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


def pages_for_tokens(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` position-aligned tokens."""
    return max(0, -(-int(n_tokens) // int(page_size)))


@dataclass
class PoolStats:
    num_pages: int
    pages_in_use: int
    peak_in_use: int
    allocs: int
    alloc_failures: int
    shared_pages: int = 0       # pages currently held by >1 table

    @property
    def utilization(self) -> float:
        return self.pages_in_use / max(1, self.num_pages)


class PagePool:
    """Fixed-size page allocator with refcounts and free-list reuse.

    Page ids are ``[0, num_pages)``; id ``num_pages`` is reserved as the
    out-of-range sentinel the device scatter uses with ``mode="drop"``.
    ``alloc`` hands out pages at refcount 1; ``incref`` adds holders
    (prefix sharing / fork); ``free`` *decrements* and only returns the
    page to the free list when the last holder lets go, bumping the
    page's generation counter so :class:`PrefixCache` entries pointing
    at it go stale.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(f"bad pool geometry {num_pages}x{page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # allocation-pressure callback: invoked with the page shortfall
        # when the free list can't cover an alloc, BEFORE the alloc
        # fails — the prefix retention cache hooks in here to evict its
        # least-recently-used retained pages on demand
        self.pressure_hook: Optional[Callable[[int], int]] = None
        # LIFO free list: recently freed pages are reused first (their
        # pool lines are more likely to still be resident in HBM caches).
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref = [0] * num_pages          # 0 = free
        self._gen = [0] * num_pages          # bumped on each real free
        self.free_events = 0                 # total pages ever freed —
                                             # cheap liveness version for
                                             # prefix-match memoization
        self._allocs = 0
        self._failures = 0
        self._peak = 0

    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def generation(self, page: int) -> int:
        return self._gen[page]

    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """Allocate ``n`` pages at refcount 1, or None (and no change)
        if unavailable.  On a free-list shortfall the pressure hook (if
        set) gets one chance to reclaim retained pages first."""
        if n > len(self._free) and self.pressure_hook is not None:
            self.pressure_hook(n - len(self._free))
        if n > len(self._free):
            self._failures += 1
            return None
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._ref[p] = 1
        self._allocs += n
        self._peak = max(self._peak, self.pages_in_use)
        return out

    def incref(self, pages: Sequence[int]) -> None:
        """Add a holder to live pages (COW attach / fork)."""
        for p in pages:
            if not (0 <= p < self.num_pages) or self._ref[p] <= 0:
                raise ValueError(f"incref of non-live page {p}")
            self._ref[p] += 1

    def free(self, pages: Sequence[int]) -> int:
        """Drop one reference per page; pages reaching refcount 0 return
        to the free list (generation bumped).  Returns how many pages
        were actually freed (refcounts never go negative — a drop past
        zero raises, it is a double free)."""
        freed = 0
        for p in pages:
            if not (0 <= p < self.num_pages):
                raise ValueError(f"freeing invalid page {p}")
            if self._ref[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._gen[p] += 1
                self._free.append(p)
                freed += 1
        self.free_events += freed
        return freed

    def stats(self) -> PoolStats:
        return PoolStats(self.num_pages, self.pages_in_use, self._peak,
                         self._allocs, self._failures,
                         sum(1 for r in self._ref if r > 1))


class BlockTables:
    """Per-slot block tables over a shared :class:`PagePool`.

    ``table(slot)`` is an ``(max_blocks,)`` int32 row; unassigned blocks
    are ``-1``.  The stacked ``(n_slots, max_blocks)`` array is what the
    jitted decode step consumes each tick.

    Copy-on-write: blocks attached through :meth:`fork` (prefix sharing)
    are *shared* — this slot may read them but never write.  Prefill
    splices consume :meth:`writable_row`, which masks shared blocks (and
    any block whose page has other holders) to ``-1`` so the device
    scatter drops those writes; a decode write landing in a shared block
    goes through :meth:`ensure_for_position`'s COW step first: allocate
    a private page, queue a ``(src, dst)`` device page copy (drained by
    the engine backend via :meth:`drain_copies`), drop the shared
    reference, repoint the table.
    """

    def __init__(self, pool: PagePool, n_slots: int, max_blocks: int):
        self.pool = pool
        self.n_slots = int(n_slots)
        self.max_blocks = int(max_blocks)
        self._tables = np.full((n_slots, max_blocks), -1, np.int32)
        self._owned: Dict[int, List[int]] = {s: [] for s in range(n_slots)}
        self._shared: Dict[int, set] = {s: set() for s in range(n_slots)}
        # live context length per slot (tokens the next decode step may
        # attend, incl. the one it writes); 0 = inactive.  Maintained by
        # ensure_for_position/release and consumed by the flash-decode
        # kernel's scalar-prefetch operands every tick.
        self._lens = np.zeros((n_slots,), np.int32)
        self._pending_copies: List[Tuple[int, int]] = []
        self.cow_copies = 0
        self.forked_pages = 0

    # ------------------------------------------------------------------
    def as_array(self) -> np.ndarray:
        return self._tables

    def context_lens(self) -> np.ndarray:
        return self._lens

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def n_blocks(self, slot: int) -> int:
        return len(self._owned[slot])

    def shared_blocks(self, slot: int) -> set:
        return set(self._shared[slot])

    def writable_row(self, slot: int) -> np.ndarray:
        """The slot's table row with every non-writable block masked to
        -1: blocks attached via :meth:`fork`, plus any block whose page
        has other holders (a preemption-resume re-splice must not
        rewrite pages a sharer is attending — the values are identical
        only up to the prefill bucket's rounding)."""
        row = self._tables[slot].copy()
        for i, page in enumerate(self._owned[slot]):
            if i in self._shared[slot] or self.pool.refcount(page) > 1:
                row[i] = -1
        return row

    def fork(self, slot: int, pages: Sequence[int]) -> None:
        """Attach ``pages`` as this slot's first blocks, copy-on-write.

        The pages must be live (held by their current owner(s)); they
        are increfed and marked shared — reads are free, writes go
        through the COW step in :meth:`ensure_for_position`.  The slot's
        table must be empty (the engine always releases a slot before
        reusing it)."""
        if self._owned[slot]:
            raise ValueError(f"fork into non-empty slot {slot}")
        if len(pages) > self.max_blocks:
            raise ValueError(f"fork of {len(pages)} blocks > max_blocks")
        self.pool.incref(pages)
        self._owned[slot] = list(pages)
        self._tables[slot, :len(pages)] = pages
        self._shared[slot] = set(range(len(pages)))
        self.forked_pages += len(pages)

    def adopt_shared(self, slot: int, blk: int, page: int) -> None:
        """Swap an owned, NOT-YET-WRITTEN block for a shared page
        (mid-prefill prefix catch-up: a cohort peer registered this
        chunk's page after we were admitted).  The old page goes back to
        the pool; the adopted page is increfed and marked shared exactly
        like a :meth:`fork` attach, so splices/chunk writes skip it."""
        if blk in self._shared[slot]:
            raise ValueError(f"block {blk} of slot {slot} already shared")
        old = self._owned[slot][blk]
        self.pool.incref([page])
        self.pool.free([old])
        self._owned[slot][blk] = page
        self._tables[slot, blk] = page
        self._shared[slot].add(blk)
        self.forked_pages += 1

    def ensure_blocks(self, slot: int, n_blocks: int) -> bool:
        """Grow ``slot``'s table to ``n_blocks`` blocks.  Returns False —
        with no partial allocation — when the pool can't supply them."""
        if n_blocks > self.max_blocks:
            raise ValueError(
                f"request needs {n_blocks} blocks > max_blocks={self.max_blocks}")
        need = n_blocks - len(self._owned[slot])
        if need <= 0:
            return True
        pages = self.pool.alloc(need)
        if pages is None:
            return False
        start = len(self._owned[slot])
        self._owned[slot].extend(pages)
        self._tables[slot, start:start + len(pages)] = pages
        return True

    def ensure_for_position(self, slot: int, pos: int) -> bool:
        """Make sure the page holding token position ``pos`` exists AND
        is writable by this slot, and record the slot's live context
        length (``pos + 1``: the engine calls this for the position the
        next decode step writes, which is also the last position that
        step attends).

        If the target block is a shared attach (fork / prefix sharing),
        this is the copy-on-write point: allocate a private page, queue
        the device page copy, release the shared reference.  Returns
        False (no state change beyond any earlier whole-block growth)
        when the pool cannot supply the page — the engine preempts a
        victim and retries."""
        blk = pos // self.pool.page_size
        if not self.ensure_blocks(slot, blk + 1):
            return False
        if blk in self._shared[slot]:
            if not self._cow(slot, blk):
                return False
        self._lens[slot] = pos + 1
        return True

    def _cow(self, slot: int, blk: int) -> bool:
        src = self._owned[slot][blk]
        new = self.pool.alloc(1)
        if new is None:
            return False
        dst = new[0]
        self._pending_copies.append((src, dst))
        self.pool.free([src])               # drop the shared reference
        self._owned[slot][blk] = dst
        self._tables[slot, blk] = dst
        self._shared[slot].discard(blk)
        self.cow_copies += 1
        return True

    def drain_copies(self) -> List[Tuple[int, int]]:
        """The (src, dst) device page copies queued by COW since the
        last drain.  The engine backend applies them (pool[dst] =
        pool[src] for every KV layer stack) before the next device step
        that could read or write those pages."""
        out = self._pending_copies
        self._pending_copies = []
        return out

    def release(self, slot: int) -> int:
        """Drop every page reference held by ``slot``; returns how many
        pages actually returned to the free list (shared pages survive
        with their remaining holders)."""
        pages = self._owned[slot]
        freed = self.pool.free(pages) if pages else 0
        self._owned[slot] = []
        self._shared[slot] = set()
        self._tables[slot, :] = -1
        self._lens[slot] = 0
        return freed


# ---------------------------------------------------------------------------
# Prefix registry: hash-keyed page-aligned prompt chunks -> live pool pages
# ---------------------------------------------------------------------------
@dataclass
class _PrefixEntry:
    page: int
    gen: int
    tokens: np.ndarray          # the chunk's tokens, for exact validation


@dataclass
class PrefixStats:
    lookups: int
    hits: int                   # lookups that attached >= 1 page
    pages_attached: int         # total pages attached instead of allocated
    tokens_shared: int
    entries: int
    retained: int = 0           # pages currently held by the retention LRU
    evictions: int = 0          # retained pages released under pressure


class PrefixCache:
    """Hash-keyed registry of page-aligned prompt chunks.

    Keys chain: ``key_i = H(key_{i-1} || tokens_i)``, so looking up a
    prompt walks its chunks left to right and stops at the first miss —
    a match is always a *prefix* match, and two prompts sharing chunk
    contents at different positions never collide.  Values are pool page
    ids validated lazily against the pool's refcount (page still live)
    and generation (page not freed+reused) plus an exact token compare
    (hash collisions can't corrupt a cache hit).

    Only full pages strictly below the registrant's prompt length are
    registered: their contents are immutable for the registrant's
    lifetime (decode writes start at the prompt boundary; resume
    re-splices are masked off shared pages by
    :meth:`BlockTables.writable_row`), which is what makes attaching
    them read-only safe.

    **Retention** (``retain_pages > 0``): without it, registered pages
    die with their last holder — a straggler admitted after its cohort
    finished re-prefills from scratch.  The retention LRU takes one
    extra reference on every registered page, so the page (and its
    registry entry) outlives the cohort; under allocation pressure the
    pool's pressure hook calls :meth:`evict_for` and retained pages
    with no other holder are released (generation bump lazily
    invalidates their entries).

    Eviction is **group-aware and deepest-first**: pages are grouped by
    their prefix *root* (the chain key of chunk 0), groups form the LRU
    (matches and re-registrations refresh a group), and within the
    least-recently-used group the DEEPEST chunks evict first.  Evicting
    the chain head would make the whole prefix unmatchable while its
    deeper pages stayed pinned; tail-first eviction instead degrades a
    cold prefix to a shorter — still useful — one.
    """

    def __init__(self, pool: PagePool, retain_pages: int = 0):
        self.pool = pool
        self.page_size = pool.page_size
        self._entries: Dict[bytes, _PrefixEntry] = {}
        self.writes = 0         # registry mutation version (register /
                                # prune) — with pool.free_events it keys
                                # the engine's admission-hint memo
        self._lookups = 0
        self._hits = 0
        self._pages_attached = 0
        self._tokens_shared = 0
        self.retain_pages = int(retain_pages)
        # page -> (generation, prefix root, chunk depth); roots form the
        # LRU (OrderedDict order = least... most recently used)
        self._retained: Dict[int, Tuple[int, bytes, int]] = {}
        self._groups: "OrderedDict[bytes, None]" = OrderedDict()
        self._evictions = 0
        if self.retain_pages > 0:
            pool.pressure_hook = self.evict_for

    # ------------------------------------------------------------------
    @staticmethod
    def _chain(parent: bytes, chunk: np.ndarray) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(parent)
        h.update(np.ascontiguousarray(chunk, np.int32).tobytes())
        return h.digest()

    def _chunks(self, tokens: np.ndarray):
        ps = self.page_size
        tokens = np.asarray(tokens, np.int32)
        for i in range(len(tokens) // ps):
            yield i, tokens[i * ps:(i + 1) * ps]

    def _live(self, e: _PrefixEntry) -> bool:
        return (self.pool.refcount(e.page) > 0
                and self.pool.generation(e.page) == e.gen)

    # ------------------------------------------------------------------
    def match(self, tokens: np.ndarray) -> List[int]:
        """Pool pages holding this prompt's longest registered full-page
        prefix (possibly empty).  Stale entries met on the walk are
        pruned.  Pure lookup — attaching (incref) is the caller's move
        via :meth:`BlockTables.fork`, recorded via
        :meth:`count_attach` (so the admission hint and the splice can
        share ONE match walk without double-counting stats)."""
        key = b""
        root: Optional[bytes] = None
        pages: List[int] = []
        for _, chunk in self._chunks(tokens):
            key = self._chain(key, chunk)
            if root is None:
                root = key
            e = self._entries.get(key)
            if e is None:
                break
            if not self._live(e):
                del self._entries[key]      # freed+reused page: prune
                self.writes += 1
                break
            if not np.array_equal(e.tokens, chunk):
                break                       # hash collision: live entry,
                                            # different chunk — keep it
            pages.append(e.page)
        if pages and root in self._groups:  # hit refreshes the group LRU
            self._groups.move_to_end(root)
        return pages

    # -- retention LRU (group-aware, deepest-first eviction) -------------
    def _retain(self, page: int, root: bytes, depth: int) -> None:
        if self.retain_pages <= 0:
            return
        if page not in self._retained:
            self.pool.incref([page])
            self._retained[page] = (self.pool.generation(page), root,
                                    depth)
        self._groups[root] = None
        self._groups.move_to_end(root)
        # cap: shed pages nobody else holds (in-use pages may ride over
        # the cap — retaining them costs no free-list capacity, and they
        # fall out on the first pressure call after their cohort)
        excess = len(self._retained) - self.retain_pages
        if excess > 0:
            self.evict_for(excess)

    def evictable(self) -> int:
        """Retained pages an eviction pass could return to the free list
        right now (no holder besides the retention reference) — what the
        engine adds to its admission free-page headroom."""
        return sum(1 for p in self._retained
                   if self.pool.refcount(p) == 1)

    def evict_for(self, n: int) -> int:
        """Release up to ``n`` retained pages that have no other holder:
        least-recently-used prefix GROUP first, deepest chunks within a
        group first — a cold prefix shrinks from its tail (shorter
        matches keep working) instead of losing its chain head (which
        would orphan every deeper page while they stayed pinned).
        Returns how many pages actually reached the free list.  Pages
        still held by live requests keep their retention (dropping it
        would free nothing)."""
        freed = 0
        for root in list(self._groups):
            if freed >= n:
                break
            members = sorted(
                (p for p, (_, r, _d) in self._retained.items()
                 if r == root),
                key=lambda p: -self._retained[p][2])      # deepest first
            for page in members:
                if freed >= n:
                    break
                if self.pool.refcount(page) == 1:
                    del self._retained[page]
                    freed += self.pool.free([page])
                    self._evictions += 1
            if not any(r == root
                       for (_, r, _d) in self._retained.values()):
                self._groups.pop(root, None)
        return freed

    def count_attach(self, n_pages: int) -> None:
        """Record one attach decision (called once per splice)."""
        self._lookups += 1
        if n_pages:
            self._hits += 1
            self._pages_attached += n_pages
            self._tokens_shared += n_pages * self.page_size

    def _sweep(self) -> None:
        """Drop every entry whose page died (freed or freed+reused).
        Live entries are bounded by the pool size — each references a
        live page at its current generation — so sweeping whenever the
        table outgrows a pool-sized bound keeps the registry O(pool)
        instead of O(total requests ever served)."""
        n = len(self._entries)
        self._entries = {k: e for k, e in self._entries.items()
                         if self._live(e)}
        self.writes += n - len(self._entries)

    def register(self, tokens: np.ndarray, block_pages: Sequence[int]
                 ) -> int:
        """Register the full-page chunks of ``tokens`` (all positions
        strictly below ``len(tokens)``) against the slot's block pages.
        Existing live entries are kept (first registrant wins — its page
        is the one sharers already hold); stale ones are replaced.
        Returns the number of entries written."""
        _, wrote = self.register_prefix(tokens, block_pages)
        return wrote

    def register_prefix(self, tokens: np.ndarray,
                        block_pages: Sequence[int],
                        state: Optional[Tuple] = None
                        ) -> Tuple[Tuple, int]:
        """Incremental :meth:`register` for chunked prefill: resume the
        chain from ``state`` (the opaque value a previous call returned
        for a strict prefix of the same ``tokens``) so each chunk of a
        long prompt registers its new full pages in O(chunk) instead of
        re-hashing the whole prefix.  Returns ``(state, wrote)``."""
        if len(self._entries) > max(64, 2 * self.pool.num_pages):
            self._sweep()
        ps = self.page_size
        tokens = np.asarray(tokens, np.int32)
        key, i, root = state if state is not None else (b"", 0, None)
        wrote = 0
        n = min(len(tokens) // ps, len(block_pages))
        while i < n:
            chunk = tokens[i * ps:(i + 1) * ps]
            key = self._chain(key, chunk)
            if root is None:
                root = key                   # prefix family = chunk-0 key
            e = self._entries.get(key)
            if e is not None and self._live(e) and \
                    np.array_equal(e.tokens, chunk):
                self._retain(e.page, root, i)
            else:
                page = int(block_pages[i])
                self._entries[key] = _PrefixEntry(
                    page, self.pool.generation(page), chunk.copy())
                self._retain(page, root, i)
                wrote += 1
            i += 1
        self.writes += wrote
        return (key, i, root), wrote

    def stats(self) -> PrefixStats:
        return PrefixStats(self._lookups, self._hits, self._pages_attached,
                           self._tokens_shared, len(self._entries),
                           len(self._retained), self._evictions)
