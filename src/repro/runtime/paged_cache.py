"""Paged KV-cache block-pool allocator (host side).

vLLM-style paging for the serving engine: the device KV cache is one
shared pool of fixed-size pages ``(num_pages, page_size, heads, head_dim)``
per attention layer stack, and each request owns a *block table* mapping
its token blocks ``t // page_size`` to pool pages.  Memory then scales
with the tokens actually resident instead of ``n_slots × max_seq``.

The layout is position-aligned: token ``t`` of a request always lives at
``(block_table[t // page_size], t % page_size)``, so the attention mask
can be derived from implied positions (``block·page_size + slot``) and no
per-slot position array has to be stored or cleared — a freed page can be
handed to the next request without touching device memory, because stale
slots are masked out by the new owner's shorter context.

This module is pure host bookkeeping (free list + per-slot tables);
the device-side gather/scatter lives in ``repro.models.layers``
(:func:`attention_decode_paged`) and ``repro.models.transformer``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


def pages_for_tokens(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` position-aligned tokens."""
    return max(0, -(-int(n_tokens) // int(page_size)))


@dataclass
class PoolStats:
    num_pages: int
    pages_in_use: int
    peak_in_use: int
    allocs: int
    alloc_failures: int

    @property
    def utilization(self) -> float:
        return self.pages_in_use / max(1, self.num_pages)


class PagePool:
    """Fixed-size page allocator with free-list reuse.

    Page ids are ``[0, num_pages)``; id ``num_pages`` is reserved as the
    out-of-range sentinel the device scatter uses with ``mode="drop"``.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(f"bad pool geometry {num_pages}x{page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # LIFO free list: recently freed pages are reused first (their
        # pool lines are more likely to still be resident in HBM caches).
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._is_free = [True] * num_pages      # O(1) double-free guard
        self._allocs = 0
        self._failures = 0
        self._peak = 0

    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """Allocate ``n`` pages, or None (and no change) if unavailable."""
        if n > len(self._free):
            self._failures += 1
            return None
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._is_free[p] = False
        self._allocs += n
        self._peak = max(self._peak, self.pages_in_use)
        return out

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not (0 <= p < self.num_pages):
                raise ValueError(f"freeing invalid page {p}")
            if self._is_free[p]:
                raise ValueError(f"double free of page {p}")
            self._is_free[p] = True
            self._free.append(p)

    def stats(self) -> PoolStats:
        return PoolStats(self.num_pages, self.pages_in_use, self._peak,
                         self._allocs, self._failures)


class BlockTables:
    """Per-slot block tables over a shared :class:`PagePool`.

    ``table(slot)`` is an ``(max_blocks,)`` int32 row; unassigned blocks
    are ``-1``.  The stacked ``(n_slots, max_blocks)`` array is what the
    jitted decode step consumes each tick.
    """

    def __init__(self, pool: PagePool, n_slots: int, max_blocks: int):
        self.pool = pool
        self.n_slots = int(n_slots)
        self.max_blocks = int(max_blocks)
        self._tables = np.full((n_slots, max_blocks), -1, np.int32)
        self._owned: Dict[int, List[int]] = {s: [] for s in range(n_slots)}
        # live context length per slot (tokens the next decode step may
        # attend, incl. the one it writes); 0 = inactive.  Maintained by
        # ensure_for_position/release and consumed by the flash-decode
        # kernel's scalar-prefetch operands every tick.
        self._lens = np.zeros((n_slots,), np.int32)

    # ------------------------------------------------------------------
    def as_array(self) -> np.ndarray:
        return self._tables

    def context_lens(self) -> np.ndarray:
        return self._lens

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def n_blocks(self, slot: int) -> int:
        return len(self._owned[slot])

    def ensure_blocks(self, slot: int, n_blocks: int) -> bool:
        """Grow ``slot``'s table to ``n_blocks`` blocks.  Returns False —
        with no partial allocation — when the pool can't supply them."""
        if n_blocks > self.max_blocks:
            raise ValueError(
                f"request needs {n_blocks} blocks > max_blocks={self.max_blocks}")
        need = n_blocks - len(self._owned[slot])
        if need <= 0:
            return True
        pages = self.pool.alloc(need)
        if pages is None:
            return False
        start = len(self._owned[slot])
        self._owned[slot].extend(pages)
        self._tables[slot, start:start + len(pages)] = pages
        return True

    def ensure_for_position(self, slot: int, pos: int) -> bool:
        """Make sure the page holding token position ``pos`` exists, and
        record the slot's live context length (``pos + 1``: the engine
        calls this for the position the next decode step writes, which
        is also the last position that step attends)."""
        ok = self.ensure_blocks(slot, pos // self.pool.page_size + 1)
        if ok:
            self._lens[slot] = pos + 1
        return ok

    def release(self, slot: int) -> int:
        """Free every page owned by ``slot``; returns how many."""
        pages = self._owned[slot]
        n = len(pages)
        if n:
            self.pool.free(pages)
        self._owned[slot] = []
        self._tables[slot, :] = -1
        self._lens[slot] = 0
        return n
