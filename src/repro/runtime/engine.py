"""Serving engine: event-emitting tick loop over contiguous or paged KV.

Continuous-batching slot model: a fixed decode batch of `n_slots`; each
slot holds one request's cache and an independent position counter (the
decode step takes a (B,) position vector, so ragged progress is native).
New requests prefill (jitted, padded to `prefill_buckets`) and splice
their cache in; finished slots free immediately.

With ``chunked_prefill=True`` (paged, attention-only archs) the
whole-prompt pass disappears entirely: admission reserves the prompt's
pages and sets a *chunk frontier*, and each tick advances at most
``prefill_chunk`` tokens of prefill — one fused scatter+attend kernel
call (`repro.kernels.paged_prefill`) that writes the chunk's K/V
straight into the slot's pool pages and attends context + in-chunk
causal prefix — before the batched decode step runs over the
*decoding* slots (mid-prefill slots are masked out of the decode:
table rows -1, context lens 0).  A long prompt therefore costs every
concurrent decode at most one chunk of latency per tick instead of a
whole-prompt stall; preemption can land between chunks (the victim
re-prefills its context seq, greedy-identical); and prefix-cache hits
skip fully-shared chunks' kernel calls outright — including
mid-prefill catch-up adoption when a same-prefix cohort peer registers
pages first, and post-cohort hits through the retention LRU
(``prefix_retain_pages``).

The engine is a **reentrant tick loop**, not a batch-and-drain box:
:meth:`Engine.tick` advances every active slot by one decode step and
publishes typed events (:mod:`repro.runtime.events`) the moment they
happen — ``TokenEvent`` per sampled token, ``FinishEvent`` /
``PreemptEvent`` / ``ExpireEvent`` on lifecycle edges — through a
subscriber/queue bus (``Engine.subscribe`` / ``Engine.event_queue``).
:meth:`Engine.run` is now just a convenience driver over ``tick()``;
callers that stream (``launch/serve.py --stream``) drive ticks
themselves and drain the queue in between.  :meth:`Engine.cancel`
aborts a request wherever it is — queued requests leave the scheduler,
in-flight requests give their slot and pages back **in the same tick**
(the ``FinishEvent(reason="cancelled")`` carries the freed page count
as the receipt).

Two cache backends behind one interface:

  * **contiguous** (legacy): each slot owns a `max_seq`-sized ring-buffer
    region — memory is `n_slots × max_seq` regardless of actual lengths.
  * **paged**: all slots share one pool of fixed-size KV pages addressed
    through per-request block tables (`repro.runtime.paged_cache`), with
    the gather/scatter over page indices inside the jitted decode step.
    Memory scales with resident tokens; when the pool runs dry the
    scheduler preempts a victim and re-queues it.  With
    ``prefix_sharing=True`` the backend keeps a hash-keyed
    :class:`~repro.runtime.paged_cache.PrefixCache`: requests whose
    prompts share page-aligned prefix chunks attach to the existing
    pool pages copy-on-write (refcounted fork) instead of allocating
    and re-writing them — the common pages of N same-prompt requests
    exist once.

Admission/preemption policy lives in `repro.runtime.scheduler` (weighted
priority classes with an aging term, deadlines, class-aware victim
selection); serving counters in `repro.runtime.metrics`.  Weights may be
fp (bf16) or PTQ1.61-quantized (QLinear pytrees) — the same jitted step
serves both, which is the point of the paper-integrated runtime: sub-2-bit
weights cut the decode weight-traffic term ~10× (EXPERIMENTS.md
§Roofline), which is exactly why the KV cache, not the weights, becomes
the serving bottleneck.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models import transformer as T
from repro.models.common import Parallel
from repro.models.param import materialize
from repro.runtime.events import (EventBus, ExpireEvent, FinishEvent,
                                  PreemptEvent, TokenEvent)
from repro.runtime.metrics import EngineMetrics
from repro.runtime.paged_cache import (BlockTables, PagePool, PrefixCache,
                                       pages_for_tokens)
from repro.runtime.scheduler import DEFAULT_CLASS, Scheduler

Tree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int = 32
    temperature: float = 0.0
    priority: str = DEFAULT_CLASS
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    expired: bool = False               # deadline passed while queued
    cancelled: bool = False             # aborted via Engine.cancel
    preemptions: int = 0
    deadline_t: Optional[float] = None  # absolute (scheduler clock)
    admit_seq: int = 0                  # set by the scheduler on admit
    prompt_cap: Optional[int] = None    # engine's max prefill length

    def n_prompt_tokens(self) -> int:
        """Tokens a (re-)prefill must cover: the prompt plus any tokens
        already generated before a preemption (minus the pending one).

        ``prompt_cap`` (the engine's decode ceiling, max_seq-1) is a
        safety bound for admission page accounting; in practice it never
        binds — fresh prompts are truncated below it at submit and a
        resume seq stops at max_seq-2 because generation ends at
        position max_seq-1 (`_start` asserts this)."""
        n = len(self.prompt) + max(0, len(self.out_tokens) - 1)
        return min(n, self.prompt_cap) if self.prompt_cap is not None else n


# ---------------------------------------------------------------------------
# Cache backends
# ---------------------------------------------------------------------------
class _ContiguousBackend:
    """Legacy per-slot ring-buffer caches: (B, max_seq) regions."""

    name = "contiguous"

    def __init__(self, eng: "Engine"):
        self.eng = eng
        cache_decl = M.init_caches(eng.cfg, eng.par, eng.n_slots, eng.max_seq)
        self.caches = materialize(cache_decl, jax.random.PRNGKey(0))
        self._decode = jax.jit(functools.partial(
            M.decode_step, eng.cfg, eng.par, max_seq=eng.max_seq))
        self._splice = jax.jit(functools.partial(M.splice_prefill, eng.cfg))

    def free_pages(self) -> Optional[int]:
        return None                      # slots pre-reserve max_seq

    def page_util(self) -> Optional[float]:
        return None

    def splice(self, slot: int, cache1: Tree, n_tokens: int,
               seq: Optional[np.ndarray] = None,
               shared: Optional[list] = None) -> None:
        self.caches = self._splice(self.caches, cache1,
                                   jnp.int32(slot))

    def ensure_capacity(self, slot: int, pos: int) -> bool:
        return True                      # region covers max_seq by design

    def release(self, slot: int) -> int:
        return 0                         # region is reused on next splice

    def decode(self, params, toks, pos):
        logits, self.caches = self._decode(params, toks, pos, self.caches)
        return logits


class _PagedBackend:
    """Shared page pool + per-slot block tables (see paged_cache.py)."""

    name = "paged"

    def __init__(self, eng: "Engine", page_size: int, pool_pages: int,
                 use_kernel: bool = True, prefix_sharing: bool = False,
                 cache_dtype=None, prefix_retain_pages: int = 0):
        self.eng = eng
        max_blocks = pages_for_tokens(eng.max_seq, page_size)
        self.pool = PagePool(pool_pages, page_size)
        self.tables = BlockTables(self.pool, eng.n_slots, max_blocks)
        self.prefix = (PrefixCache(self.pool,
                                   retain_pages=prefix_retain_pages)
                       if prefix_sharing else None)
        # admission-hint memo: rid -> matched pages, valid for one
        # (registry writes, pool frees) version — a blocked head is
        # hashed once, not once per tick, and splice reuses the pages
        self._hint_cache: Dict[int, list] = {}
        self._hint_ver = None
        cache_decl = M.init_paged_caches(eng.cfg, eng.par, eng.n_slots,
                                         pool_pages, page_size,
                                         dtype=cache_dtype)
        self.caches = materialize(cache_decl, jax.random.PRNGKey(0))
        self._decode = jax.jit(functools.partial(
            M.decode_step_paged, eng.cfg, eng.par, max_seq=eng.max_seq,
            use_kernel=use_kernel))
        self._splice = jax.jit(functools.partial(
            M.splice_prefill_paged, eng.cfg))
        self._copy = jax.jit(functools.partial(M.copy_pages, eng.cfg))
        # chunked-prefill step (one request, one chunk): start/length
        # ride as traced scalars so every chunk of every prompt hits the
        # ONE compiled (1, prefill_chunk) shape — no bucket ladder
        self._chunk_step = jax.jit(functools.partial(
            M.prefill_step_paged, eng.cfg, eng.par, max_seq=eng.max_seq,
            use_kernel=use_kernel))
        self.prefill_chunk_calls = 0
        self.prefill_kv_read_bytes = 0

    @property
    def page_size(self) -> int:
        return self.pool.page_size

    def free_pages(self) -> Optional[int]:
        """Admission headroom: the free list plus whatever the prefix
        retention LRU could evict on demand (the pool's pressure hook
        reclaims those inside ``alloc`` when the free list falls
        short)."""
        free = self.pool.free_pages
        if self.prefix is not None and self.prefix.retain_pages > 0:
            free += self.prefix.evictable()
        return free

    def page_util(self) -> Optional[float]:
        return self.pool.pages_in_use / self.pool.num_pages

    def shared_page_hint(self, rid: int, seq: np.ndarray) -> int:
        """Pages a prefix-cache attach would effectively save for
        ``seq`` right now (admission accounting: the scheduler subtracts
        them from the head's page need).  Registry state cannot change
        between this hint and the attach in ``splice`` — both happen
        inside the same host-side admission pass — so the matched pages
        are memoized by rid and the splice reuses them instead of
        re-hashing the prompt.  The memo survives across ticks until
        any registry write or page free (either can only change match
        results when it happens), so a queued head blocked on free
        pages does not pay O(prompt) hashing per tick.

        With retention on, matched pages whose ONLY holder is the
        retention LRU must NOT be discounted: :meth:`free_pages`
        already counts them as evictable headroom, and the attach pins
        them (refcount 2) so they stop being evictable the moment the
        request starts — discounting them too would double-count and
        admit a head whose remaining pages cannot actually be
        allocated.  Refcounts are re-read on every call (they can move
        without a free event)."""
        if self.prefix is None:
            return 0
        ver = (self.prefix.writes, self.pool.free_events)
        if ver != self._hint_ver:
            self._hint_cache.clear()
            self._hint_ver = ver
        if rid not in self._hint_cache:
            self._hint_cache[rid] = self.prefix.match(seq)
        pages = self._hint_cache[rid]
        if self.prefix.retain_pages > 0:
            return len(pages) - sum(1 for p in pages
                                    if self.pool.refcount(p) == 1)
        return len(pages)

    def _apply_cow(self) -> None:
        pairs = self.tables.drain_copies()
        if pairs:
            src = jnp.asarray([s for s, _ in pairs], jnp.int32)
            dst = jnp.asarray([d for _, d in pairs], jnp.int32)
            self.caches = self._copy(self.caches, src, dst)

    def splice(self, slot: int, cache1: Tree, n_tokens: int,
               seq: Optional[np.ndarray] = None,
               shared: Optional[list] = None) -> None:
        if self.prefix is not None and seq is not None:
            if shared is None:      # no admission hint: match here
                shared = self.prefix.match(seq)
            self.prefix.count_attach(len(shared))
            if shared:
                self.tables.fork(slot, shared)
        ok = self.tables.ensure_blocks(
            slot, pages_for_tokens(n_tokens, self.page_size))
        assert ok, "admission must reserve prompt pages first"
        self._apply_cow()
        # shared (forked) blocks are masked to -1: the device scatter
        # drops those writes — the pages already hold these tokens' KV
        bt_row = jnp.asarray(self.tables.writable_row(slot))
        self.caches = self._splice(self.caches, cache1, jnp.int32(slot),
                                   bt_row)
        if self.prefix is not None and seq is not None:
            self.prefix.register(seq, self.tables.owned(slot))

    def ensure_capacity(self, slot: int, pos: int) -> bool:
        return self.tables.ensure_for_position(slot, pos)

    def release(self, slot: int) -> int:
        return self.tables.release(slot)

    def decode(self, params, toks, pos, active=None):
        """One batched decode step.  ``active`` (np bool (n_slots,) or
        None) masks slots that must not decode this tick — mid-prefill
        slots under chunked prefill: their block-table rows go to -1
        (the device write is dropped) and their context lens to 0 (the
        kernel zero-fills), all in host numpy so the jitted signature
        never changes."""
        self._apply_cow()
        bt = self.tables.as_array()
        lens = self.tables.context_lens()
        if active is not None:
            bt = np.where(active[:, None], bt, -1)
            lens = np.where(active, lens, 0)
        logits, self.caches = self._decode(params, toks, pos, self.caches,
                                           jnp.asarray(bt),
                                           jnp.asarray(lens))
        return logits

    def prefill_chunk(self, params, toks, slot: int, start: int,
                      length: int):
        """Advance ``slot``'s prefill by one chunk: fused scatter+attend
        straight into the slot's pool pages (kernel or XLA fallback —
        see models.layers.attention_prefill_paged).  Returns the chunk's
        last-live-row logits (1, V)."""
        self._apply_cow()
        bt_read = jnp.asarray(self.tables.as_array()[slot])
        bt_write = jnp.asarray(self.tables.writable_row(slot))
        logits, self.caches = self._chunk_step(
            params, toks, self.caches, bt_read, bt_write,
            jnp.int32(start), jnp.int32(length))
        self.prefill_chunk_calls += 1
        from repro.kernels import autotune
        eng = self.eng
        hkv = eng.par.kv_heads_run(eng.cfg.n_kv_heads, eng.cfg.n_heads)
        self.prefill_kv_read_bytes += eng.cfg.n_layers * \
            autotune.paged_prefill_read_bytes(
                start, length, self.page_size, hkv, eng.cfg.head_dim_)
        return logits


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
class Engine:
    def __init__(self, cfg: ArchConfig, par: Parallel, params: Tree,
                 *, n_slots: int = 4, max_seq: int = 512,
                 prefill_buckets=(64, 256), seed: int = 0,
                 paged: bool = False, page_size: int = 16,
                 pool_pages: Optional[int] = None,
                 paged_kernel: bool = True,
                 prefix_sharing: bool = False,
                 prefix_retain_pages: int = 0,
                 chunked_prefill: bool = False,
                 prefill_chunk: int = 64,
                 prefill_chunks_per_tick: int = 1,
                 cache_dtype=None,
                 scheduler: Optional[Scheduler] = None,
                 metrics: Optional[EngineMetrics] = None,
                 fuse_projections: bool = False,
                 time_phases: bool = True):
        if fuse_projections:
            # N-fuse QKV / gate+up so each block's decode step issues 2
            # projection matmuls instead of 5 (exact for fp weights;
            # QLinear leaves stay unfused here — quantize with
            # quantize_params_data_free(fuse=True) for fused packed
            # layouts).
            params = T.fuse_params_for_decode(params)
        self.cfg, self.par, self.params = cfg, par, params
        self.n_slots, self.max_seq = n_slots, max_seq
        self.buckets = tuple(sorted(b for b in prefill_buckets
                                    if b <= max_seq)) or (max_seq,)
        if chunked_prefill:
            if not paged:
                raise ValueError("chunked_prefill requires paged=True "
                                 "(chunks scatter into pool pages)")
            kinds = {k for s in cfg.stages for k in s.pattern}
            if not kinds <= set(T.ATTN_KINDS):
                raise ValueError(
                    f"chunked_prefill supports attention-only stages, "
                    f"got kinds {sorted(kinds)} — recurrent cells carry "
                    f"sequential state across chunks; serve this arch "
                    f"with the whole-prompt path")
            if prefill_chunk <= 0 or prefill_chunk % page_size:
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} must be a positive "
                    f"multiple of page_size={page_size} (chunks must "
                    f"tile into pages)")
            if prefill_chunks_per_tick <= 0:
                raise ValueError("prefill_chunks_per_tick must be >= 1")
        self.chunked_prefill = chunked_prefill
        self.prefill_chunk = prefill_chunk
        self.prefill_chunks_per_tick = prefill_chunks_per_tick
        # a prefill of max_seq tokens would put the first decode write at
        # position max_seq (past every cache layout) — cap prompts one
        # short.  Chunked prefill has no bucket ladder (every chunk is
        # the same compiled shape), so only the decode ceiling caps it.
        self.max_prompt = (max_seq - 1 if chunked_prefill
                           else min(self.buckets[-1], max_seq - 1))
        self.key = jax.random.PRNGKey(seed)
        self.scheduler = scheduler or Scheduler()
        self.metrics = metrics or EngineMetrics()
        self.events = EventBus()

        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros((n_slots,), np.int32)
        self.cur_tok = np.zeros((n_slots,), np.int32)
        self.temps = np.zeros((n_slots,), np.float32)

        if paged:
            if page_size <= 0:
                raise ValueError(f"page_size must be positive, got {page_size}")
            if pool_pages is None:
                pool_pages = n_slots * pages_for_tokens(max_seq, page_size)
            # paged_kernel: paged decode attention through the Pallas
            # flash-decode kernel on feasible shapes (default); False
            # pins the XLA-gather reference path (oracle / debugging)
            self.backend = _PagedBackend(
                self, page_size, pool_pages,
                use_kernel=paged_kernel,
                prefix_sharing=prefix_sharing,
                cache_dtype=cache_dtype,
                prefix_retain_pages=prefix_retain_pages)
        else:
            if prefix_sharing:
                raise ValueError("prefix_sharing requires paged=True "
                                 "(sharing lives in the page allocator)")
            self.backend = _ContiguousBackend(self)
        if prefix_retain_pages and not prefix_sharing:
            raise ValueError("prefix_retain_pages requires "
                             "prefix_sharing=True (retention extends the "
                             "prefix cache's hit window)")
        # chunked prefill: slot -> in-progress prefill frontier state
        # ({"seq", "frontier", "resumed"}); a slot present here holds a
        # request but must not decode yet
        self._prefill_state: Dict[int, Dict[str, Any]] = {}

        self._prefill = jax.jit(functools.partial(
            M.prefill, cfg, par, max_seq=max_seq))
        self._sample = jax.jit(_sample_batched)
        self._rid = 0
        self._requests: Dict[int, Request] = {}
        self._tick_no = 0
        self._in_tick = False
        self._pending_cancels: List[int] = []
        # per-phase timing: each jitted shape's FIRST call includes the
        # XLA compile and is recorded under "<phase>_compile" so the
        # "prefill"/"decode" series are pure steady-state step times.
        # ``time_phases=False`` drops the block_until_ready sync on the
        # decode hot path entirely (on an accelerator it costs one extra
        # host-device round trip per generated token).
        self.time_phases = time_phases
        self._warm_shapes: set = set()

    def _timed(self, phase: str, shape_key, fn):
        """Run fn() and record its blocked wall time under ``phase`` (or
        ``phase_compile`` for the first call at ``shape_key``)."""
        if not self.time_phases:
            return fn()
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        if (phase, shape_key) in self._warm_shapes:
            self.metrics.on_phase_time(phase, dt)
        else:
            self._warm_shapes.add((phase, shape_key))
            self.metrics.on_phase_time(phase + "_compile", dt)
            # compile wall time must not masquerade as an inter-token
            # gap in the TBT series (it already shows up in TTFT)
            self.metrics.on_stall()
        return out

    # -- event API ------------------------------------------------------
    def subscribe(self, cb):
        """Register a callback for every engine event.  Callbacks run
        inside ``tick()``; ``Engine.cancel`` called from one is deferred
        to the end of the current tick (still the same tick)."""
        return self.events.subscribe(cb)

    def event_queue(self, maxlen: Optional[int] = None):
        """A drainable event queue (collections.deque) — the streaming
        consumer's API: drain with popleft() between ticks."""
        return self.events.queue(maxlen)

    def _emit(self, ev) -> None:
        self.events.publish(ev)

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 32,
               temperature: float = 0.0,
               deadline_s: Optional[float] = None,
               priority: str = DEFAULT_CLASS) -> Request:
        prompt = np.asarray(prompt, np.int32)
        # prompts longer than the largest prefill bucket are left-truncated
        # (keep the most recent tokens — standard serving behavior)
        if len(prompt) > self.max_prompt:
            prompt = prompt[-self.max_prompt:]
        if not self.scheduler.has_class(priority):
            raise ValueError(f"unknown priority class {priority!r}")
        self._rid += 1
        deadline_t = (self.scheduler.clock() + deadline_s
                      if deadline_s is not None else None)
        # page-need cap for admission: resumes keep full context up to
        # the decode ceiling (max_seq-1), not the fresh-prompt bucket cap
        r = Request(self._rid, prompt, max_new, temperature,
                    priority=priority, deadline_t=deadline_t,
                    prompt_cap=self.max_seq - 1)
        if max_new <= 0:                     # degenerate: nothing to do
            r.done = True
            self.metrics.on_submit(r.rid, priority)
            self.metrics.on_finish(r.rid)
            self._emit(FinishEvent(r.rid, "empty", 0, 0, self._tick_no))
            return r
        if isinstance(self.backend, _PagedBackend):
            # max_new >= 1 here (degenerate requests returned above), so
            # this bound covers admission's prompt+first-decode-page need
            need = pages_for_tokens(
                min(len(prompt) + max_new, self.max_seq),
                self.backend.page_size)
            if need > self.backend.pool.num_pages:
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self.backend.pool.num_pages}; grow --pool-pages")
        # rid -> request, for cancel(); registered only once the request
        # is truly accepted, and dropped at every terminal transition
        # (finish/expire/cancel) so a long-running tick loop does not
        # retain every request ever served
        self._requests[r.rid] = r
        self.scheduler.enqueue(r)
        self.metrics.on_submit(r.rid, priority)
        return r

    def _bucket(self, s: int) -> int:
        for b in self.buckets:
            if s <= b:
                return b
        # implicit top bucket: fresh prompts are truncated to max_prompt
        # ≤ buckets[-1] before this, so only preemption resumes land here
        # (their seq can reach max_seq-2 and must keep full context/
        # positions — one extra prefill compile, no truncation)
        return self.max_seq

    def _context_seq(self, r: Request) -> np.ndarray:
        """The token sequence a (re-)prefill of ``r`` must cover — the
        prompt, plus for preemption resumes the already-generated tokens
        minus the pending one (re-fed as the next decode input).  Also
        what the prefix cache matches/registers against."""
        if r.out_tokens:
            return np.concatenate([r.prompt,
                                   np.asarray(r.out_tokens[:-1], np.int32)])
        return r.prompt

    # ------------------------------------------------------------------
    def _start_chunked(self, slot: int, r: Request) -> None:
        """Occupy ``slot`` for chunked prefill: attach any shared prefix
        pages, reserve the prompt's pages, and set the chunk frontier —
        the actual compute happens chunk-by-chunk in
        :meth:`_advance_prefill` across subsequent ticks.  Chunks fully
        covered by prefix-cache pages are skipped outright (zero
        prefill-kernel calls for them): the frontier starts at the
        shared-page boundary, capped one page short of the prompt end so
        the final chunk always runs (its last-row logits seed the first
        sampled token)."""
        be = self.backend
        seq = self._context_seq(r)
        assert len(seq) <= self.max_seq - 1, (len(seq), self.max_seq)
        s = len(seq)
        ps = be.page_size
        shared: list = []
        if be.prefix is not None:
            hinted = be._hint_cache.pop(r.rid, None)
            shared = hinted if hinted is not None else be.prefix.match(seq)
            be.prefix.count_attach(len(shared))
            if shared:
                be.tables.fork(slot, shared)
        ok = be.tables.ensure_blocks(slot, pages_for_tokens(s, ps))
        assert ok, "admission must reserve prompt pages first"
        skip = min(len(shared) * ps, ((s - 1) // ps) * ps)
        if skip:
            self.metrics.on_prefill_skip(skip)
        self.slot_req[slot] = r
        self.temps[slot] = r.temperature
        st: Dict[str, Any] = {"seq": seq, "frontier": skip,
                              "resumed": bool(r.out_tokens)}
        if be.prefix is not None:
            # the admission match is current as of this version — the
            # catch-up pass in _advance_prefill only re-matches when a
            # peer has registered (or the pool freed) since
            st["match_ver"] = (be.prefix.writes, be.pool.free_events)
        self._prefill_state[slot] = st

    def _advance_prefill(self, slot: int) -> int:
        """Run ONE chunk of ``slot``'s in-progress prefill; on reaching
        the prompt end, graduate the slot to decoding (sample the first
        token from the final chunk's logits, or re-feed the pending
        token on a preemption resume).  Returns the live tokens
        processed."""
        st = self._prefill_state[slot]
        r = self.slot_req[slot]
        be = self.backend
        seq = st["seq"]
        s = len(seq)
        ps = be.page_size
        # ---- mid-prefill prefix catch-up: a cohort peer may have
        # registered pages for chunks we have not computed yet (it was
        # admitted with us, ahead of us in chunk order) — adopt its
        # pages and fast-forward the frontier, skipping those chunks'
        # kernel calls outright.  Memoized on the registry/pool version
        # so an unchanged registry costs no re-hash.
        if be.prefix is not None:
            ver = (be.prefix.writes, be.pool.free_events)
            if st.get("match_ver") != ver:
                st["match_ver"] = ver
                matched = be.prefix.match(seq)
                skip_to = min(len(matched) * ps, ((s - 1) // ps) * ps)
                if skip_to > st["frontier"]:
                    for blk in range(st["frontier"] // ps, skip_to // ps):
                        be.tables.adopt_shared(slot, blk, matched[blk])
                    be.prefix.count_attach(
                        skip_to // ps - st["frontier"] // ps)
                    self.metrics.on_prefill_skip(skip_to - st["frontier"])
                    st["frontier"] = skip_to
        start = st["frontier"]
        c = self.prefill_chunk
        length = min(c, s - start)
        toks = np.zeros((1, c), np.int32)
        toks[0, :length] = seq[start:start + length]
        logits = self._timed(
            "prefill_chunk", c,
            lambda: self.backend.prefill_chunk(self.params,
                                               jnp.asarray(toks), slot,
                                               start, length))
        st["frontier"] = start + length
        self.metrics.on_prefill_chunk(length)
        # register the freshly-completed full pages as they appear (so
        # cohort peers can catch up mid-prefill, not only after we
        # finish); the chain state makes each call O(chunk)
        if be.prefix is not None:
            st["reg_state"], _ = be.prefix.register_prefix(
                seq[:st["frontier"]], be.tables.owned(slot),
                st.get("reg_state"))
            st["match_ver"] = (be.prefix.writes, be.pool.free_events)
        if st["frontier"] < s:
            return length
        # ---- prompt complete: graduate to decoding -------------------
        del self._prefill_state[slot]
        # the first decode page: admission accounted prompt+1, but other
        # slots may have grown into that page since — preempt on
        # shortfall (possibly evicting this very request, which then
        # resumes from the queue)
        while self.slot_req[slot] is r and \
                not be.ensure_capacity(slot, s):
            if not self._preempt_for(slot):
                raise RuntimeError(
                    "page pool exhausted with no preemption victim; "
                    "grow --pool-pages")
        if self.slot_req[slot] is not r:
            return length               # evicted ourselves: re-queued
        if st["resumed"]:
            tok = r.out_tokens[-1]
        else:
            tok = int(self._sample(logits.astype(jnp.float32),
                                   self._next_key(),
                                   jnp.asarray([r.temperature],
                                               jnp.float32))[0])
            r.out_tokens.append(tok)
            self.metrics.on_token(r.rid)
            self._emit(TokenEvent(r.rid, tok, len(r.out_tokens) - 1,
                                  self._tick_no))
            if len(r.out_tokens) >= r.max_new:   # max_new=1: done here
                r.done = True
                self.metrics.on_finish(r.rid)
                self._requests.pop(r.rid, None)
                freed = self.backend.release(slot)
                self.slot_req[slot] = None
                self._emit(FinishEvent(r.rid, "max_new",
                                       len(r.out_tokens), freed,
                                       self._tick_no))
                return length
        self.pos[slot] = s
        self.cur_tok[slot] = tok
        return length

    def _start(self, slot: int, r: Request) -> None:
        """(Re-)prefill `r` and occupy `slot`.

        Fresh requests prefill their prompt and sample the first token
        from the prefill logits.  Preempted requests prefill the prompt
        plus their already-generated tokens (minus the pending one, which
        is re-fed as the next decode input) so decoding continues where
        it stopped.
        """
        if self.chunked_prefill:
            return self._start_chunked(slot, r)
        resumed = bool(r.out_tokens)
        seq = self._context_seq(r)
        # a resume seq is bounded by the decode ceiling (generation stops
        # at pos max_seq-1), so the full context always fits a bucket
        assert len(seq) <= self.max_seq - 1, (len(seq), self.max_seq)
        s = len(seq)
        b = self._bucket(s)
        toks = np.full((1, b), 0, np.int32)
        toks[0, -s:] = seq                       # left-pad
        # pad positions are -1: masked out of attention and never written
        # into KV storage (ring p=-1 / paged scatter drop)
        idx = np.arange(b, dtype=np.int32)
        positions = np.where(idx >= b - s, idx - (b - s), -1)[None]
        batch = {"tokens": jnp.asarray(toks),
                 "positions": jnp.asarray(positions)}
        logits, cache1 = self._timed(
            "prefill", b, lambda: self._prefill(self.params, batch))
        be = self.backend
        shared = None
        if isinstance(be, _PagedBackend) and be.prefix is not None:
            # the admission pass just matched this request's prefix; no
            # free or registration can have happened since — reuse it
            shared = be._hint_cache.pop(r.rid, None)
        be.splice(slot, cache1, s, seq, shared)
        # this slot decodes at position s THIS tick, after the growth
        # pass already ran — admission reserved the page (prompt+1)
        ok = self.backend.ensure_capacity(slot, s)
        assert ok, "admission must reserve the first decode page"
        if resumed:
            tok = r.out_tokens[-1]
        else:
            tok = int(self._sample(logits[:, -1].astype(jnp.float32),
                                   self._next_key(),
                                   jnp.asarray([r.temperature],
                                               jnp.float32))[0])
            r.out_tokens.append(tok)
            self.metrics.on_token(r.rid)
            self._emit(TokenEvent(r.rid, tok, len(r.out_tokens) - 1,
                                  self._tick_no))
            if len(r.out_tokens) >= r.max_new:   # max_new=1: done at prefill
                r.done = True
                self.metrics.on_finish(r.rid)
                self._requests.pop(r.rid, None)
                freed = self.backend.release(slot)
                self._emit(FinishEvent(r.rid, "max_new", len(r.out_tokens),
                                       freed, self._tick_no))
                return
        self.slot_req[slot] = r
        self.pos[slot] = s
        self.cur_tok[slot] = tok
        self.temps[slot] = r.temperature

    def _admit(self) -> None:
        for r in self.scheduler.expire():
            r.expired = True
            r.done = True
            self.metrics.on_expire(r.rid)
            self._requests.pop(r.rid, None)
            self._emit(ExpireEvent(r.rid, self._tick_no))
        shared_hint = None
        if isinstance(self.backend, _PagedBackend) and \
                self.backend.prefix is not None:
            shared_hint = (lambda req:
                           self.backend.shared_page_hint(
                               req.rid, self._context_seq(req)))
        for slot in range(self.n_slots):
            # while, not if: a max_new=1 request finishes AT prefill and
            # leaves the slot free — keep admitting into it so a tick
            # with an admissible queue never reports "nothing to do"
            while self.slot_req[slot] is None:
                r = self.scheduler.next_admissible(
                    self.backend.free_pages(),
                    getattr(self.backend, "page_size", 1),
                    shared_pages=shared_hint)
                if r is None:
                    return
                self.metrics.on_admit(r.rid)
                self._start(slot, r)

    # ------------------------------------------------------------------
    def _preempt_for(self, slot: int) -> bool:
        """Free pages by evicting a victim so `slot` can grow.  Returns
        False when no victim exists (pool too small for this request)."""
        running = {s: r for s, r in enumerate(self.slot_req)
                   if r is not None}
        victim = self.scheduler.choose_victim(running, exclude=slot)
        if victim is None:
            return False
        r = self.slot_req[victim]
        r.preemptions += 1
        self.metrics.on_preempt(r.rid)
        freed = self.backend.release(victim)
        self.slot_req[victim] = None
        # a mid-prefill victim abandons its chunk frontier: the resume
        # re-prefills the same context seq from the top (or from its
        # prefix-cache hit), reproducing identical greedy tokens
        self._prefill_state.pop(victim, None)
        self._emit(PreemptEvent(r.rid, victim, freed, self._tick_no))
        # front of its class queue: the victim becomes that class's
        # longest-waiting request and is re-admitted first (no
        # preemption starvation)
        self.scheduler.enqueue(r, front=True)
        return True

    def _grow_caches(self) -> None:
        """Before a decode tick, every active slot needs storage for the
        token it is about to write at `pos`.  On pool exhaustion, preempt
        and retry; preempting may evict the very slot we were growing."""
        for slot in range(self.n_slots):
            while self.slot_req[slot] is not None and \
                    slot not in self._prefill_state and \
                    not self.backend.ensure_capacity(slot, int(self.pos[slot])):
                if not self._preempt_for(slot):
                    raise RuntimeError(
                        "page pool exhausted with no preemption victim; "
                        "grow --pool-pages")

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    # ------------------------------------------------------------------
    def cancel(self, rid: int) -> bool:
        """Abort a request.  Queued requests leave the scheduler at
        once; in-flight requests release their slot and return their
        pages to the pool immediately — within the current tick when
        called from an event callback (processing is deferred to the
        tick's end so the decode loop is never mutated under itself).
        Emits ``FinishEvent(reason="cancelled", freed_pages=...)``.
        Returns False when the rid is unknown or already finished.

        A *deferred* cancel (issued from inside a callback) returns
        True optimistically: if the request reaches its natural finish
        later in the same tick, the cancel becomes a no-op and the
        terminal event is the natural ``FinishEvent`` (``max_new`` /
        ``max_seq``), not a cancelled one — consumers must treat ANY
        FinishEvent for the rid as terminal, never wait specifically
        for ``reason="cancelled"``."""
        r = self._requests.get(rid)
        if r is None or r.done:
            return False
        if self._in_tick:
            self._pending_cancels.append(rid)
            return True
        return self._do_cancel(rid)

    def _do_cancel(self, rid: int) -> bool:
        r = self._requests.get(rid)
        if r is None or r.done:
            return False
        freed = 0
        if self.scheduler.remove(rid) is None:
            # not queued: must be in a slot
            for slot, rr in enumerate(self.slot_req):
                if rr is not None and rr.rid == rid:
                    freed = self.backend.release(slot)
                    self.slot_req[slot] = None
                    self._prefill_state.pop(slot, None)
                    break
        r.done = True
        r.cancelled = True
        self.metrics.on_cancel(rid)
        self._requests.pop(rid, None)
        self._emit(FinishEvent(rid, "cancelled", len(r.out_tokens), freed,
                               self._tick_no))
        return True

    def running(self) -> List[Tuple[int, Request]]:
        """Active (slot, request) pairs, in slot order."""
        return [(s, r) for s, r in enumerate(self.slot_req)
                if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(len(self.scheduler)
                    or any(r is not None for r in self.slot_req))

    def prefix_stats(self):
        """Prefix-cache counters (None unless prefix_sharing is on):
        lookups/hits, pages attached instead of allocated (the pages
        saved by sharing), tokens covered, live entries — plus the
        tables' COW copy count."""
        be = self.backend
        if not isinstance(be, _PagedBackend) or be.prefix is None:
            return None
        st = be.prefix.stats()
        return {"lookups": st.lookups, "hits": st.hits,
                "pages_attached": st.pages_attached,
                "tokens_shared": st.tokens_shared,
                "entries": st.entries,
                "retained": st.retained,
                "evictions": st.evictions,
                "cow_copies": be.tables.cow_copies,
                "forked_pages": be.tables.forked_pages}

    # ------------------------------------------------------------------
    def tick(self) -> bool:
        """One batched decode tick across all active slots; returns
        False when nothing was running or admissible.

        Growth runs BEFORE admission: if running slots need pages, any
        preemption happens first, and only then is the freed capacity
        offered to the queue — admitting first would make the fresh
        request the newest (default victim) and throw away its entire
        prefill in the same tick."""
        self._tick_no += 1
        self._in_tick = True
        try:
            return self._tick_body()
        finally:
            self._in_tick = False
            pending, self._pending_cancels = self._pending_cancels, []
            for rid in pending:          # deferred from event callbacks:
                self._do_cancel(rid)     # still "the same tick"

    def _tick_body(self) -> bool:
        self._grow_caches()
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        self.metrics.on_tick(
            self.scheduler.queue_depth,
            sum(r is not None for r in self.slot_req),
            self.backend.page_util())
        # ---- chunked-prefill phase: a bounded slice of prefill work
        # interleaves with (instead of stalling) the decode step below.
        # The scheduler picks which in-progress prefill advances
        # (class-weighted, FCFS within a class); the budget caps the
        # prefill compute any single tick can absorb, which is what
        # bounds the inter-token gap of concurrent decodes.
        if self._prefill_state:
            for _ in range(self.prefill_chunks_per_tick):
                if not self._prefill_state:
                    break
                sl = self.scheduler.next_prefill_slot(
                    {s: self.slot_req[s] for s in self._prefill_state})
                self._advance_prefill(sl)
        decoding = [s for s, r in enumerate(self.slot_req)
                    if r is not None and s not in self._prefill_state]
        if not decoding:
            return True                 # pure-prefill tick
        active = None
        if self._prefill_state:
            active = np.zeros((self.n_slots,), bool)
            active[decoding] = True
        toks = jnp.asarray(self.cur_tok)
        pos = jnp.asarray(self.pos)
        logits = self._timed(
            "decode", self.backend.name,
            lambda: (self.backend.decode(self.params, toks, pos, active)
                     if active is not None else
                     self.backend.decode(self.params, toks, pos)))
        # one vectorized device sample across all slots (no per-slot
        # logits round-trips through numpy)
        next_toks = np.asarray(self._sample(logits.astype(jnp.float32),
                                            self._next_key(),
                                            jnp.asarray(self.temps)))
        for slot, r in enumerate(self.slot_req):
            if r is None or slot in self._prefill_state:
                continue
            tok = int(next_toks[slot])
            r.out_tokens.append(tok)
            self.metrics.on_token(r.rid)
            self.pos[slot] += 1
            self.cur_tok[slot] = tok
            self._emit(TokenEvent(r.rid, tok, len(r.out_tokens) - 1,
                                  self._tick_no))
            # a cancel issued from an event callback is DEFERRED (see
            # tick()'s finally), so r.done cannot flip under this loop
            if len(r.out_tokens) >= r.max_new or \
                    self.pos[slot] >= self.max_seq - 1:
                reason = ("max_new" if len(r.out_tokens) >= r.max_new
                          else "max_seq")
                r.done = True
                self.metrics.on_finish(r.rid)
                self._requests.pop(r.rid, None)
                freed = self.backend.release(slot)
                self.slot_req[slot] = None
                self._emit(FinishEvent(r.rid, reason, len(r.out_tokens),
                                       freed, self._tick_no))
        return True

    # back-compat alias: tick() is the reentrant primitive
    step = tick

    def run(self, max_ticks: int = 10_000, on_tick=None) -> None:
        """Drive ticks until the queue and slots drain.  ``on_tick``
        (no-arg callable) runs after every tick — streaming consumers
        drain their event queue there (see launch/serve.py) without
        re-implementing the loop, its stall guard, or the runaway
        ``max_ticks`` bound."""
        ticks = 0
        while self.has_work and ticks < max_ticks:
            if not self.tick():
                # nothing admissible and nothing running: only possible
                # when queued work cannot fit yet — avoid spinning
                if not any(r is not None for r in self.slot_req) and \
                        len(self.scheduler):
                    raise RuntimeError(
                        "queued request can never be admitted "
                        "(pool too small for its prompt)")
            if on_tick is not None:
                on_tick()
            ticks += 1


def _sample_batched(logits: jax.Array, key, temps: jax.Array) -> jax.Array:
    """Vectorized sampling for all slots in one device call.

    logits (B,V) f32; temps (B,): <=0 means greedy.  Per-slot subkeys
    keep slots independent; the greedy lane ignores the key entirely so
    temperature-0 decoding is deterministic.
    """
    greedy = jnp.argmax(logits, axis=-1)
    keys = jax.random.split(key, logits.shape[0])
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
