"""Serving engine: batched prefill/decode with per-slot positions.

Continuous-batching slot model: a fixed decode batch of `n_slots`; each
slot holds one request's cache region and an independent position counter
(the decode step takes a (B,) position vector, so ragged progress is
native).  New requests prefill (jitted, padded to `prefill_buckets`) and
splice their cache into the slot; finished slots free immediately.

Weights may be fp (bf16) or PTQ1.61-quantized (QLinear pytrees) — the
same jitted step serves both, which is the point of the paper-integrated
runtime: sub-2-bit weights cut the decode weight-traffic term ~10×
(EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models.common import Parallel
from repro.models.param import abstractify, materialize

Tree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, par: Parallel, params: Tree,
                 *, n_slots: int = 4, max_seq: int = 512,
                 prefill_buckets=(64, 256), seed: int = 0):
        self.cfg, self.par, self.params = cfg, par, params
        self.n_slots, self.max_seq = n_slots, max_seq
        self.buckets = tuple(sorted(b for b in prefill_buckets
                                    if b <= max_seq)) or (max_seq,)
        self.key = jax.random.PRNGKey(seed)

        # batched decode cache (concrete zeros from the abstract decl)
        cache_decl = M.init_caches(cfg, par, n_slots, max_seq)
        self.caches = materialize(cache_decl, jax.random.PRNGKey(0))
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros((n_slots,), np.int32)
        self.cur_tok = np.zeros((n_slots,), np.int32)

        self._decode = jax.jit(functools.partial(
            M.decode_step, cfg, par, max_seq=max_seq))
        self._prefill = jax.jit(functools.partial(
            M.prefill, cfg, par, max_seq=max_seq))
        self._queue: List[Request] = []
        self._rid = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 32,
               temperature: float = 0.0) -> Request:
        self._rid += 1
        r = Request(self._rid, np.asarray(prompt, np.int32), max_new,
                    temperature)
        self._queue.append(r)
        return r

    def _bucket(self, s: int) -> int:
        for b in self.buckets:
            if s <= b:
                return b
        return self.buckets[-1]

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or not self._queue:
                continue
            r = self._queue.pop(0)
            s = len(r.prompt)
            b = self._bucket(s)
            toks = np.full((1, b), 0, np.int32)
            toks[0, -s:] = r.prompt                  # left-pad
            positions = np.maximum(
                np.arange(b, dtype=np.int32) - (b - s), 0)[None]
            batch = {"tokens": jnp.asarray(toks),
                     "positions": jnp.asarray(positions)}
            logits, cache1 = self._prefill(self.params, batch)
            # splice request cache (leading layer dims stay; batch dim = 1)
            self.caches = jax.tree.map(
                lambda c, c1: c.at[:, slot].set(c1[:, 0]), self.caches, cache1)
            tok = self._sample(logits[:, -1], r)
            r.out_tokens.append(int(tok))
            self.slot_req[slot] = r
            self.pos[slot] = s
            self.cur_tok[slot] = int(tok)

    def _sample(self, logits: jax.Array, r: Request) -> int:
        if r.temperature <= 0:
            return int(jnp.argmax(logits[-1] if logits.ndim > 1 else logits))
        self.key, sub = jax.random.split(self.key)
        lg = (logits[-1] if logits.ndim > 1 else logits) / r.temperature
        return int(jax.random.categorical(sub, lg))

    # ------------------------------------------------------------------
    def step(self):
        """One batched decode tick across all active slots."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        toks = jnp.asarray(self.cur_tok)
        pos = jnp.asarray(self.pos)
        logits, self.caches = self._decode(self.params, toks, pos,
                                           self.caches)
        logits = np.asarray(logits.astype(jnp.float32))
        for slot, r in enumerate(self.slot_req):
            if r is None:
                continue
            tok = self._sample(jnp.asarray(logits[slot]), r)
            r.out_tokens.append(tok)
            self.pos[slot] += 1
            self.cur_tok[slot] = tok
            if len(r.out_tokens) >= r.max_new or self.pos[slot] >= self.max_seq - 1:
                r.done = True
                self.slot_req[slot] = None
        return True

    def run(self, max_ticks: int = 10_000) -> None:
        ticks = 0
        while (self._queue or any(self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
