"""Serving engine: batched prefill/decode over contiguous slots or paged KV.

Continuous-batching slot model: a fixed decode batch of `n_slots`; each
slot holds one request's cache and an independent position counter (the
decode step takes a (B,) position vector, so ragged progress is native).
New requests prefill (jitted, padded to `prefill_buckets`) and splice
their cache in; finished slots free immediately.

Two cache backends behind one interface:

  * **contiguous** (legacy): each slot owns a `max_seq`-sized ring-buffer
    region — memory is `n_slots × max_seq` regardless of actual lengths.
  * **paged**: all slots share one pool of fixed-size KV pages addressed
    through per-request block tables (`repro.runtime.paged_cache`), with
    the gather/scatter over page indices inside the jitted decode step.
    Memory scales with resident tokens; when the pool runs dry the
    scheduler preempts a victim and re-queues it.

Admission/preemption policy lives in `repro.runtime.scheduler` (FCFS,
deadlines, victim selection); serving counters in
`repro.runtime.metrics`.  Weights may be fp (bf16) or PTQ1.61-quantized
(QLinear pytrees) — the same jitted step serves both, which is the point
of the paper-integrated runtime: sub-2-bit weights cut the decode
weight-traffic term ~10× (EXPERIMENTS.md §Roofline), which is exactly
why the KV cache, not the weights, becomes the serving bottleneck.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models import transformer as T
from repro.models.common import Parallel
from repro.models.param import materialize
from repro.runtime.metrics import EngineMetrics
from repro.runtime.paged_cache import (BlockTables, PagePool,
                                       pages_for_tokens)
from repro.runtime.scheduler import Scheduler

Tree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    expired: bool = False               # deadline passed while queued
    preemptions: int = 0
    deadline_t: Optional[float] = None  # absolute (scheduler clock)
    admit_seq: int = 0                  # set by the scheduler on admit
    prompt_cap: Optional[int] = None    # engine's max prefill length

    def n_prompt_tokens(self) -> int:
        """Tokens a (re-)prefill must cover: the prompt plus any tokens
        already generated before a preemption (minus the pending one).

        ``prompt_cap`` (the engine's decode ceiling, max_seq-1) is a
        safety bound for admission page accounting; in practice it never
        binds — fresh prompts are truncated below it at submit and a
        resume seq stops at max_seq-2 because generation ends at
        position max_seq-1 (`_start` asserts this)."""
        n = len(self.prompt) + max(0, len(self.out_tokens) - 1)
        return min(n, self.prompt_cap) if self.prompt_cap is not None else n


# ---------------------------------------------------------------------------
# Cache backends
# ---------------------------------------------------------------------------
class _ContiguousBackend:
    """Legacy per-slot ring-buffer caches: (B, max_seq) regions."""

    name = "contiguous"

    def __init__(self, eng: "Engine"):
        self.eng = eng
        cache_decl = M.init_caches(eng.cfg, eng.par, eng.n_slots, eng.max_seq)
        self.caches = materialize(cache_decl, jax.random.PRNGKey(0))
        self._decode = jax.jit(functools.partial(
            M.decode_step, eng.cfg, eng.par, max_seq=eng.max_seq))
        self._splice = jax.jit(functools.partial(M.splice_prefill, eng.cfg))

    def free_pages(self) -> Optional[int]:
        return None                      # slots pre-reserve max_seq

    def page_util(self) -> Optional[float]:
        return None

    def splice(self, slot: int, cache1: Tree, n_tokens: int) -> None:
        self.caches = self._splice(self.caches, cache1,
                                   jnp.int32(slot))

    def ensure_capacity(self, slot: int, pos: int) -> bool:
        return True                      # region covers max_seq by design

    def release(self, slot: int) -> None:
        pass                             # region is reused on next splice

    def decode(self, params, toks, pos):
        logits, self.caches = self._decode(params, toks, pos, self.caches)
        return logits


class _PagedBackend:
    """Shared page pool + per-slot block tables (see paged_cache.py)."""

    name = "paged"

    def __init__(self, eng: "Engine", page_size: int, pool_pages: int,
                 use_kernel: bool = True):
        self.eng = eng
        max_blocks = pages_for_tokens(eng.max_seq, page_size)
        self.pool = PagePool(pool_pages, page_size)
        self.tables = BlockTables(self.pool, eng.n_slots, max_blocks)
        cache_decl = M.init_paged_caches(eng.cfg, eng.par, eng.n_slots,
                                         pool_pages, page_size)
        self.caches = materialize(cache_decl, jax.random.PRNGKey(0))
        self._decode = jax.jit(functools.partial(
            M.decode_step_paged, eng.cfg, eng.par, max_seq=eng.max_seq,
            use_kernel=use_kernel))
        self._splice = jax.jit(functools.partial(
            M.splice_prefill_paged, eng.cfg))

    @property
    def page_size(self) -> int:
        return self.pool.page_size

    def free_pages(self) -> Optional[int]:
        return self.pool.free_pages

    def page_util(self) -> Optional[float]:
        return self.pool.pages_in_use / self.pool.num_pages

    def splice(self, slot: int, cache1: Tree, n_tokens: int) -> None:
        ok = self.tables.ensure_blocks(
            slot, pages_for_tokens(n_tokens, self.page_size))
        assert ok, "admission must reserve prompt pages first"
        bt_row = jnp.asarray(self.tables.as_array()[slot])
        self.caches = self._splice(self.caches, cache1, jnp.int32(slot),
                                   bt_row)

    def ensure_capacity(self, slot: int, pos: int) -> bool:
        return self.tables.ensure_for_position(slot, pos)

    def release(self, slot: int) -> None:
        self.tables.release(slot)

    def decode(self, params, toks, pos):
        bt = jnp.asarray(self.tables.as_array())
        lens = jnp.asarray(self.tables.context_lens())
        logits, self.caches = self._decode(params, toks, pos, self.caches,
                                           bt, lens)
        return logits


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
class Engine:
    def __init__(self, cfg: ArchConfig, par: Parallel, params: Tree,
                 *, n_slots: int = 4, max_seq: int = 512,
                 prefill_buckets=(64, 256), seed: int = 0,
                 paged: bool = False, page_size: int = 16,
                 pool_pages: Optional[int] = None,
                 paged_kernel: bool = True,
                 scheduler: Optional[Scheduler] = None,
                 metrics: Optional[EngineMetrics] = None,
                 fuse_projections: bool = False,
                 time_phases: bool = True):
        if fuse_projections:
            # N-fuse QKV / gate+up so each block's decode step issues 2
            # projection matmuls instead of 5 (exact for fp weights;
            # QLinear leaves stay unfused here — quantize with
            # quantize_params_data_free(fuse=True) for fused packed
            # layouts).
            params = T.fuse_params_for_decode(params)
        self.cfg, self.par, self.params = cfg, par, params
        self.n_slots, self.max_seq = n_slots, max_seq
        self.buckets = tuple(sorted(b for b in prefill_buckets
                                    if b <= max_seq)) or (max_seq,)
        # a prefill of max_seq tokens would put the first decode write at
        # position max_seq (past every cache layout) — cap prompts one short
        self.max_prompt = min(self.buckets[-1], max_seq - 1)
        self.key = jax.random.PRNGKey(seed)
        self.scheduler = scheduler or Scheduler()
        self.metrics = metrics or EngineMetrics()

        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros((n_slots,), np.int32)
        self.cur_tok = np.zeros((n_slots,), np.int32)
        self.temps = np.zeros((n_slots,), np.float32)

        if paged:
            if page_size <= 0:
                raise ValueError(f"page_size must be positive, got {page_size}")
            if pool_pages is None:
                pool_pages = n_slots * pages_for_tokens(max_seq, page_size)
            # paged_kernel: paged decode attention through the Pallas
            # flash-decode kernel on feasible shapes (default); False
            # pins the XLA-gather reference path (oracle / debugging)
            self.backend = _PagedBackend(self, page_size, pool_pages,
                                         use_kernel=paged_kernel)
        else:
            self.backend = _ContiguousBackend(self)

        self._prefill = jax.jit(functools.partial(
            M.prefill, cfg, par, max_seq=max_seq))
        self._sample = jax.jit(_sample_batched)
        self._rid = 0
        # per-phase timing: each jitted shape's FIRST call includes the
        # XLA compile and is recorded under "<phase>_compile" so the
        # "prefill"/"decode" series are pure steady-state step times.
        # ``time_phases=False`` drops the block_until_ready sync on the
        # decode hot path entirely (on an accelerator it costs one extra
        # host-device round trip per generated token).
        self.time_phases = time_phases
        self._warm_shapes: set = set()

    def _timed(self, phase: str, shape_key, fn):
        """Run fn() and record its blocked wall time under ``phase`` (or
        ``phase_compile`` for the first call at ``shape_key``)."""
        if not self.time_phases:
            return fn()
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        if (phase, shape_key) in self._warm_shapes:
            self.metrics.on_phase_time(phase, dt)
        else:
            self._warm_shapes.add((phase, shape_key))
            self.metrics.on_phase_time(phase + "_compile", dt)
        return out

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 32,
               temperature: float = 0.0,
               deadline_s: Optional[float] = None) -> Request:
        prompt = np.asarray(prompt, np.int32)
        # prompts longer than the largest prefill bucket are left-truncated
        # (keep the most recent tokens — standard serving behavior)
        if len(prompt) > self.max_prompt:
            prompt = prompt[-self.max_prompt:]
        self._rid += 1
        deadline_t = (self.scheduler.clock() + deadline_s
                      if deadline_s is not None else None)
        # page-need cap for admission: resumes keep full context up to
        # the decode ceiling (max_seq-1), not the fresh-prompt bucket cap
        r = Request(self._rid, prompt, max_new, temperature,
                    deadline_t=deadline_t, prompt_cap=self.max_seq - 1)
        if max_new <= 0:                     # degenerate: nothing to do
            r.done = True
            self.metrics.on_submit(r.rid)
            self.metrics.on_finish(r.rid)
            return r
        if isinstance(self.backend, _PagedBackend):
            # max_new >= 1 here (degenerate requests returned above), so
            # this bound covers admission's prompt+first-decode-page need
            need = pages_for_tokens(
                min(len(prompt) + max_new, self.max_seq),
                self.backend.page_size)
            if need > self.backend.pool.num_pages:
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self.backend.pool.num_pages}; grow --pool-pages")
        self.scheduler.enqueue(r)
        self.metrics.on_submit(r.rid)
        return r

    def _bucket(self, s: int) -> int:
        for b in self.buckets:
            if s <= b:
                return b
        # implicit top bucket: fresh prompts are truncated to max_prompt
        # ≤ buckets[-1] before this, so only preemption resumes land here
        # (their seq can reach max_seq-2 and must keep full context/
        # positions — one extra prefill compile, no truncation)
        return self.max_seq

    # ------------------------------------------------------------------
    def _start(self, slot: int, r: Request) -> None:
        """(Re-)prefill `r` and occupy `slot`.

        Fresh requests prefill their prompt and sample the first token
        from the prefill logits.  Preempted requests prefill the prompt
        plus their already-generated tokens (minus the pending one, which
        is re-fed as the next decode input) so decoding continues where
        it stopped.
        """
        resumed = bool(r.out_tokens)
        seq = (np.concatenate([r.prompt,
                               np.asarray(r.out_tokens[:-1], np.int32)])
               if resumed else r.prompt)
        # a resume seq is bounded by the decode ceiling (generation stops
        # at pos max_seq-1), so the full context always fits a bucket
        assert len(seq) <= self.max_seq - 1, (len(seq), self.max_seq)
        s = len(seq)
        b = self._bucket(s)
        toks = np.full((1, b), 0, np.int32)
        toks[0, -s:] = seq                       # left-pad
        # pad positions are -1: masked out of attention and never written
        # into KV storage (ring p=-1 / paged scatter drop)
        idx = np.arange(b, dtype=np.int32)
        positions = np.where(idx >= b - s, idx - (b - s), -1)[None]
        batch = {"tokens": jnp.asarray(toks),
                 "positions": jnp.asarray(positions)}
        logits, cache1 = self._timed(
            "prefill", b, lambda: self._prefill(self.params, batch))
        self.backend.splice(slot, cache1, s)
        # this slot decodes at position s THIS tick, after the growth
        # pass already ran — admission reserved the page (prompt+1)
        ok = self.backend.ensure_capacity(slot, s)
        assert ok, "admission must reserve the first decode page"
        if resumed:
            tok = r.out_tokens[-1]
        else:
            tok = int(self._sample(logits[:, -1].astype(jnp.float32),
                                   self._next_key(),
                                   jnp.asarray([r.temperature],
                                               jnp.float32))[0])
            r.out_tokens.append(tok)
            self.metrics.on_token(r.rid)
            if len(r.out_tokens) >= r.max_new:   # max_new=1: done at prefill
                r.done = True
                self.metrics.on_finish(r.rid)
                self.backend.release(slot)
                return
        self.slot_req[slot] = r
        self.pos[slot] = s
        self.cur_tok[slot] = tok
        self.temps[slot] = r.temperature

    def _admit(self) -> None:
        for r in self.scheduler.expire():
            r.expired = True
            r.done = True
            self.metrics.on_expire(r.rid)
        for slot in range(self.n_slots):
            # while, not if: a max_new=1 request finishes AT prefill and
            # leaves the slot free — keep admitting into it so a tick
            # with an admissible queue never reports "nothing to do"
            while self.slot_req[slot] is None:
                r = self.scheduler.next_admissible(
                    self.backend.free_pages(),
                    getattr(self.backend, "page_size", 1))
                if r is None:
                    return
                self.metrics.on_admit(r.rid)
                self._start(slot, r)

    # ------------------------------------------------------------------
    def _preempt_for(self, slot: int) -> bool:
        """Free pages by evicting a victim so `slot` can grow.  Returns
        False when no victim exists (pool too small for this request)."""
        running = {s: r for s, r in enumerate(self.slot_req)
                   if r is not None}
        victim = self.scheduler.choose_victim(running, exclude=slot)
        if victim is None:
            return False
        r = self.slot_req[victim]
        r.preemptions += 1
        self.metrics.on_preempt(r.rid)
        self.backend.release(victim)
        self.slot_req[victim] = None
        # front of the queue: the victim becomes the longest-waiting
        # request and is re-admitted first (no preemption starvation)
        self.scheduler.enqueue(r, front=True)
        return True

    def _grow_caches(self) -> None:
        """Before a decode tick, every active slot needs storage for the
        token it is about to write at `pos`.  On pool exhaustion, preempt
        and retry; preempting may evict the very slot we were growing."""
        for slot in range(self.n_slots):
            while self.slot_req[slot] is not None and \
                    not self.backend.ensure_capacity(slot, int(self.pos[slot])):
                if not self._preempt_for(slot):
                    raise RuntimeError(
                        "page pool exhausted with no preemption victim; "
                        "grow --pool-pages")

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One batched decode tick across all active slots.

        Growth runs BEFORE admission: if running slots need pages, any
        preemption happens first, and only then is the freed capacity
        offered to the queue — admitting first would make the fresh
        request the newest (default victim) and throw away its entire
        prefill in the same tick."""
        self._grow_caches()
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        self.metrics.on_tick(
            self.scheduler.queue_depth,
            sum(r is not None for r in self.slot_req),
            self.backend.page_util())
        toks = jnp.asarray(self.cur_tok)
        pos = jnp.asarray(self.pos)
        logits = self._timed(
            "decode", self.backend.name,
            lambda: self.backend.decode(self.params, toks, pos))
        # one vectorized device sample across all slots (no per-slot
        # logits round-trips through numpy)
        next_toks = np.asarray(self._sample(logits.astype(jnp.float32),
                                            self._next_key(),
                                            jnp.asarray(self.temps)))
        for slot, r in enumerate(self.slot_req):
            if r is None:
                continue
            tok = int(next_toks[slot])
            r.out_tokens.append(tok)
            self.metrics.on_token(r.rid)
            self.pos[slot] += 1
            self.cur_tok[slot] = tok
            if len(r.out_tokens) >= r.max_new or \
                    self.pos[slot] >= self.max_seq - 1:
                r.done = True
                self.metrics.on_finish(r.rid)
                self.backend.release(slot)
                self.slot_req[slot] = None
        return True

    def run(self, max_ticks: int = 10_000) -> None:
        ticks = 0
        while (len(self.scheduler) or any(r is not None
                                          for r in self.slot_req)) \
                and ticks < max_ticks:
            if not self.step():
                # nothing admissible and nothing running: only possible
                # when queued work cannot fit yet — avoid spinning
                if not any(r is not None for r in self.slot_req) and \
                        len(self.scheduler):
                    raise RuntimeError(
                        "queued request can never be admitted "
                        "(pool too small for its prompt)")
            ticks += 1


def _sample_batched(logits: jax.Array, key, temps: jax.Array) -> jax.Array:
    """Vectorized sampling for all slots in one device call.

    logits (B,V) f32; temps (B,): <=0 means greedy.  Per-slot subkeys
    keep slots independent; the greedy lane ignores the key entirely so
    temperature-0 decoding is deterministic.
    """
    greedy = jnp.argmax(logits, axis=-1)
    keys = jax.random.split(key, logits.shape[0])
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
