"""llava-next-34b — exact assignment configuration.

source: hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000,
    stages=(Stage(("dense",), 60),),
    act="silu", frontend="vision", frontend_tokens=576,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified")
