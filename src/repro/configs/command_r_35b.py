"""command-r-35b — exact assignment configuration.

source: hf:CohereForAI/c4ai-command-r-v01; unverified
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab=256000,
    stages=(Stage(("dense",), 40),),
    act="silu", norm="layernorm", qkv_bias=False,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified")
