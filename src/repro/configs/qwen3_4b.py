"""qwen3-4b — exact assignment configuration.

source: hf:Qwen/Qwen3-8B; hf
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense",
    d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab=151936,
    stages=(Stage(("dense",), 36),),
    act="silu", qk_norm=True, tied_embeddings=True,
    source="hf:Qwen/Qwen3-8B; hf")
