"""qwen2.5-3b — exact assignment configuration.

source: hf:Qwen/Qwen2.5-0.5B; hf
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="qwen2.5-3b", family="dense",
    d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, vocab=151936,
    stages=(Stage(("dense",), 36),),
    act="silu", qkv_bias=True, tied_embeddings=True,
    source="hf:Qwen/Qwen2.5-0.5B; hf")
