"""tiny-lm — exact assignment configuration.

source: in-repo tiny subject for end-to-end PTQ experiments
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="tiny-lm", family="dense",
    d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=704, vocab=512,
    stages=(Stage(("dense",), 4),),
    act="silu",
    source="in-repo tiny subject for end-to-end PTQ experiments")
