"""llama-7b — exact assignment configuration.

source: arXiv:2302.13971 (paper's Table 1 subject)
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="llama-7b", family="dense",
    d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab=32000,
    stages=(Stage(("dense",), 32),),
    act="silu",
    source="arXiv:2302.13971 (paper's Table 1 subject)")
