"""Architecture configuration schema.

Every assigned architecture (plus the paper's own LLaMA subjects) is an
``ArchConfig``.  A config is *declarative*: model code in ``repro.models``
reads it to build parameter shapes, logical sharding axes and the forward
functions.  The same config powers 1-device smoke tests (via
``reduced()``), the 256/512-chip dry-run (full shapes, abstract values)
and the benchmarks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# Block kinds understood by repro.models.transformer
#   "dense"      : GQA attention + gated MLP
#   "moe"        : GQA attention + mixture-of-experts MLP
#   "local"      : local (windowed, causal) attention + gated MLP
#   "rglru"      : Griffin-style recurrent block (conv + RG-LRU) + gated MLP
#   "mlstm"      : xLSTM mLSTM block (internal up/down projection, no MLP)
#   "slstm"      : xLSTM sLSTM block (+ small gated FFN)
BLOCK_KINDS = ("dense", "moe", "local", "rglru", "mlstm", "slstm")


@dataclass(frozen=True)
class Stage:
    """A run of layers scanned as a unit.

    ``pattern`` is the block-kind sequence inside one superblock;
    ``repeats`` is the scan length.  Total layers = len(pattern)*repeats.
    Heterogeneous stacks (RecurrentGemma 2:1, xLSTM 7:1) become a single
    scan over superblocks so the lowered HLO stays depth-independent.
    """

    pattern: Tuple[str, ...]
    repeats: int

    def __post_init__(self):
        for k in self.pattern:
            if k not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {k!r}")

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # Dense-dispatch capacity factor used by the einsum-based token routing
    # (capacity = top_k * capacity_factor * tokens / n_experts).
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    stages: Tuple[Stage, ...]
    head_dim: Optional[int] = None   # default d_model // n_heads

    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: Optional[int] = None      # sliding-window size for "dense"/"moe"
    local_window: int = 2048               # window for "local" blocks
    rope_theta: float = 10000.0
    logit_softcap: Optional[float] = None

    # mlp
    act: str = "silu"                # silu (gated) | gelu (gated)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tied_embeddings: bool = False

    # MoE
    moe: Optional[MoEConfig] = None

    # recurrent families
    rnn_width: Optional[int] = None        # RG-LRU recurrence width
    conv_width: int = 4                    # temporal conv width (Griffin)
    mlstm_proj_factor: float = 2.0
    slstm_ff_factor: float = 8.0 / 3.0

    # encoder-decoder (seamless)
    enc_dec: bool = False
    n_enc_layers: int = 0

    # multimodal frontends are STUBS: input_specs() provides precomputed
    # embeddings of this many positions which the model consumes directly.
    frontend: Optional[str] = None         # None | "vision" | "audio"
    frontend_tokens: int = 0               # e.g. vision patch tokens per image

    # citation / provenance string from the assignment table
    source: str = ""

    # ---- derived ----------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 so the embedding/head shard 16-way TP
        (Megatron-style padding; padded logits are masked — models/model)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state is bounded (window / recurrent) so the
        long_500k cell is runnable."""
        kinds = {k for s in self.stages for k in s.pattern}
        if kinds <= {"rglru", "mlstm", "slstm", "local"}:
            return True
        # dense/moe blocks with a sliding window are also bounded
        if ("dense" in kinds or "moe" in kinds) and self.attn_window is not None:
            return True
        return False

    def n_params(self) -> int:
        """Closed-form parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.head_dim_
        n_q, n_kv = self.n_heads, self.n_kv_heads
        per_kind = {}
        attn = d * (n_q * dh) + 2 * d * (n_kv * dh) + (n_q * dh) * d
        mlp = 3 * d * self.d_ff
        per_kind["dense"] = attn + mlp
        if self.moe:
            per_kind["moe"] = attn + self.moe.n_experts * mlp + d * self.moe.n_experts
        per_kind["local"] = attn + mlp
        if self.rnn_width:
            r = self.rnn_width
            # in-proj (d->2r), conv (4r), rg-lru gates (2 r*r/heads.. approx r*r/4*2), out (r->d), mlp
            per_kind["rglru"] = d * 2 * r + self.conv_width * r + 2 * (r * r // 8) + r * d + mlp
        m_in = int(self.mlstm_proj_factor * d)
        # mlstm: up(d->2m); q/k/v are slices of the up branch in our impl;
        # gates (m->3h scalar-ish); down(m->d)
        per_kind["mlstm"] = d * 2 * m_in + 3 * m_in + m_in * d
        f = int(self.slstm_ff_factor * d)
        per_kind["slstm"] = 4 * d * d + 4 * (d // max(1, n_q)) * d + 2 * d * f + f * d
        total = self.vocab * d  # embed
        if not self.tied_embeddings:
            total += self.vocab * d
        for s in self.stages:
            for k in s.pattern:
                total += per_kind.get(k, 0) * s.repeats
        if self.enc_dec:
            # encoder blocks: dense attn + mlp, plus decoder cross-attn
            total += self.n_enc_layers * (per_kind["dense"])
            total += self.n_layers * attn  # cross attention per decoder layer
        return total

    def active_params(self) -> int:
        """Params used per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        mlp = 3 * d * self.d_ff
        dead = (self.moe.n_experts - self.moe.top_k) * mlp
        n_moe_layers = sum(s.pattern.count("moe") * s.repeats for s in self.stages)
        return self.n_params() - dead * n_moe_layers

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = {}
        scale["d_model"] = 64
        scale["n_heads"] = 4
        scale["n_kv_heads"] = max(1, min(self.n_kv_heads, 2))
        scale["head_dim"] = 16
        scale["d_ff"] = 128 if self.d_ff else 0
        scale["vocab"] = 512
        scale["rnn_width"] = 64 if self.rnn_width else None
        scale["local_window"] = 32
        scale["attn_window"] = 32 if self.attn_window else None
        scale["frontend_tokens"] = 8 if self.frontend else 0
        scale["n_enc_layers"] = 2 if self.enc_dec else 0
        # keep the pattern, shrink repeats to 1 (and cap pattern reps)
        stages = tuple(Stage(s.pattern[:8], 1) for s in self.stages[:2])
        scale["stages"] = stages
        if self.moe:
            scale["moe"] = MoEConfig(n_experts=min(self.moe.n_experts, 4),
                                     top_k=min(self.moe.top_k, 2),
                                     capacity_factor=self.moe.capacity_factor)
        return dataclasses.replace(self, **scale)


# ---------------------------------------------------------------------------
# Input-shape cells (assigned): every LM arch gets these four.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def cell_by_name(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)


def cell_applicable(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention; everything else always runs."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention architecture — 500k-token "
                       "decode state is unbounded (see DESIGN.md §4)")
    return True, ""
