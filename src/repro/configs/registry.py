"""Architecture registry: the 10 assigned archs + the paper's subjects.

Each architecture lives in its own ``configs/<id>.py`` module (exact
assignment-table configuration, ``source`` records provenance); this
registry imports and indexes them.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

_MODULES = [
    "command_r_35b", "phi4_mini_3_8b", "qwen3_4b", "qwen2_5_3b",
    "xlstm_1_3b", "recurrentgemma_2b", "llava_next_34b", "mixtral_8x22b",
    "granite_moe_1b_a400m", "seamless_m4t_medium",
    # the paper's own quantization subjects
    "llama_7b", "tiny_lm",
]

_REGISTRY: Dict[str, ArchConfig] = {}
for _m in _MODULES:
    _cfg = importlib.import_module(f"repro.configs.{_m}").CONFIG
    _REGISTRY[_cfg.name] = _cfg

ASSIGNED: List[str] = [
    "command-r-35b", "phi4-mini-3.8b", "qwen3-4b", "qwen2.5-3b",
    "xlstm-1.3b", "recurrentgemma-2b", "llava-next-34b", "mixtral-8x22b",
    "granite-moe-1b-a400m", "seamless-m4t-medium",
]


def get(name: str) -> ArchConfig:
    key = name if name in _REGISTRY else name.replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def names() -> List[str]:
    return sorted(_REGISTRY)
