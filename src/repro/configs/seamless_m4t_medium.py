"""seamless-m4t-medium — exact assignment configuration.

source: arXiv:2308.11596; hf
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=256206,
    stages=(Stage(("dense",), 12),),      # decoder stack
    act="gelu", norm="layernorm",
    enc_dec=True, n_enc_layers=12, frontend="audio",
    source="arXiv:2308.11596; hf")
