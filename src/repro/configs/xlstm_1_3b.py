"""xlstm-1.3b — exact assignment configuration.

source: arXiv:2405.04517; unverified
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304,
    stages=(Stage(("mlstm",) * 7 + ("slstm",), 6),),
    norm="layernorm", mlstm_proj_factor=2.0,
    source="arXiv:2405.04517; unverified")
