"""granite-moe-1b-a400m — exact assignment configuration.

source: hf:ibm-granite/granite-3.0-1b-a400m-base; hf
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    stages=(Stage(("moe",), 24),),
    act="silu", tied_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf")
