"""phi4-mini-3.8b — exact assignment configuration.

source: arXiv:2412.08905; hf
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=200064,
    stages=(Stage(("dense",), 32),),
    act="silu", tied_embeddings=True,
    source="arXiv:2412.08905; hf")
