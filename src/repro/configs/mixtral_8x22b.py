"""mixtral-8x22b — exact assignment configuration.

source: arXiv:2401.04088; hf
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768,
    stages=(Stage(("moe",), 56),),
    act="silu", attn_window=4096,   # SWA per assignment
    moe=MoEConfig(n_experts=8, top_k=2),
    source="arXiv:2401.04088; hf")
