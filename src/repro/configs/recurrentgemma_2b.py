"""recurrentgemma-2b — exact assignment configuration.

source: arXiv:2402.19427; hf
"""
from repro.configs.base import ArchConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000,
    stages=(Stage(("rglru", "rglru", "local"), 8),
            Stage(("rglru",), 2)),
    act="gelu", local_window=2048, rnn_width=2560, tied_embeddings=True,
    source="arXiv:2402.19427; hf")
