"""Parameter trees with logical sharding axes.

Model init functions build a tree of ``P`` leaves (shape + logical axis
names + init style).  From that single declaration we derive:

  * concrete parameter arrays (``materialize``),
  * abstract ``jax.ShapeDtypeStruct`` stand-ins for the dry-run
    (``abstractify``),
  * ``jax.sharding.PartitionSpec`` trees via a logical→mesh rule table
    (``repro.distributed.sharding``).

Logical axis vocabulary (see DESIGN.md §5):
  "embed"     model width (d_model)            → FSDP ("data") or replicated
  "heads"     attention query heads × head_dim → TP ("model")
  "kv_heads"  kv heads × head_dim              → TP ("model") (pre-replicated
                                                 to TP degree by the model)
  "ffn"       MLP hidden                       → TP ("model")
  "vocab"     vocabulary                       → TP ("model")
  "experts"   MoE expert dim                   → EP ("model" or "data")
  "rnn"       recurrence width                 → TP ("model")
  "layers"    scan dim                         → never sharded
  None        replicated small vectors
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    """Declarative parameter leaf."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | scaled (fan-in)
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} mismatch")


def is_leaf(x) -> bool:
    return isinstance(x, P)


def tree_map_params(fn: Callable[[P], Any], tree):
    return jax.tree.map(fn, tree, is_leaf=is_leaf)


def abstractify(tree):
    """P tree -> ShapeDtypeStruct tree (no allocation; dry-run input)."""
    return tree_map_params(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree)


def axes_tree(tree):
    """P tree -> logical-axes tree (same structure, tuple leaves)."""
    return tree_map_params(lambda p: p.axes, tree)


def _init_leaf(p: P, key) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "neg_ones":
        return jnp.full(p.shape, -1, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "normal":
        return (0.02 * jax.random.normal(key, p.shape, jnp.float32)).astype(p.dtype)
    if p.init == "scaled":  # fan-in scaled (1/sqrt(fan_in) over last-but-one dim)
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = 1.0 / math.sqrt(max(1, fan_in))
        return (std * jax.random.normal(key, p.shape, jnp.float32)).astype(p.dtype)
    raise ValueError(f"unknown init {p.init!r}")


def materialize(tree, key) -> Any:
    """P tree -> concrete arrays.  Deterministic per-leaf key derivation
    (path-hash folded into the base key) so init is stable under tree
    edits.

    The path hash must be ``zlib.crc32``, NOT the builtin ``hash()``:
    Python randomizes string hashing per process (PYTHONHASHSEED), so
    ``hash(path_str)`` silently gave every process DIFFERENT initial
    weights for the same seed — the root cause of the long-standing
    "~50% xlstm train-smoke flake" (some per-process init draws push the
    chaotic sLSTM trajectory to inf; nothing to do with threading)."""
    leaves = jax.tree_util.tree_leaves_with_path(tree, is_leaf=is_leaf)
    arrays = []
    for path, p in leaves:
        path_str = jax.tree_util.keystr(path)
        sub = jax.random.fold_in(
            key, zlib.crc32(path_str.encode()) % (2**31 - 1))
        arrays.append(_init_leaf(p, sub))
    treedef = jax.tree.structure(tree, is_leaf=is_leaf)
    return jax.tree.unflatten(treedef, arrays)


def count_params(tree) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(tree, is_leaf=is_leaf))


def param_bytes(tree) -> int:
    return sum(int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize
               for p in jax.tree.leaves(tree, is_leaf=is_leaf))
