"""Recurrent blocks: Griffin RG-LRU (RecurrentGemma) and xLSTM cells.

Training-time forms
-------------------
* RG-LRU: elementwise linear recurrence ``h_t = a_t*h_{t-1} + b_t`` runs as
  a log-depth ``jax.lax.associative_scan`` over the sequence.
* mLSTM: chunkwise gated-linear-attention form — O(S·L) intra-chunk
  attention + O(S/L) recurrent chunk scan carrying the (d_k × d_v) matrix
  state.  Matches the step recurrence (tested against it).
* sLSTM: strictly sequential scalar-memory cell (block-diagonal recurrent
  matrices per head) via ``lax.scan`` — inherently serial, as in the paper.

Decode-time forms are single-step state updates; the dry-run decode cells
lower these.  All weight matmuls route through ``dense`` so they quantize.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Parallel
from repro.models.linear import dense
from repro.models.param import P

Tree = Any


# ---------------------------------------------------------------------------
# RG-LRU (Griffin recurrent block): in-proj -> [conv -> RG-LRU] * gelu gate
# ---------------------------------------------------------------------------
RG_HEADS = 8  # block-diagonal gate heads (Griffin appendix)


def init_rglru(cfg: ArchConfig) -> Tree:
    d = cfg.d_model
    r = cfg.rnn_width or d
    hd = r // RG_HEADS
    return {
        "w_x": P((d, r), ("embed", "rnn"), "scaled"),
        "w_gate": P((d, r), ("embed", "rnn"), "scaled"),
        "conv_w": P((cfg.conv_width, r), (None, "rnn"), "scaled"),
        "conv_b": P((r,), ("rnn",), "zeros"),
        # block-diagonal input/recurrence gates (heads, hd, hd)
        "w_inp": P((RG_HEADS, hd, hd), (None, None, None), "scaled"),
        "w_rec": P((RG_HEADS, hd, hd), (None, None, None), "scaled"),
        "lam": P((r,), ("rnn",), "ones", jnp.float32),   # Λ (via softplus map)
        "w_out": P((r, d), ("rnn", "embed"), "scaled"),
    }


def _rg_gates(p: Tree, x: jax.Array):
    """x: (..., R) -> input gate i_t, recurrence gate r_t (block-diag heads)."""
    shp = x.shape[:-1]
    xh = x.reshape(shp + (RG_HEADS, -1)).astype(jnp.float32)
    gi = jnp.einsum("...hd,hde->...he", xh, p["w_inp"].astype(jnp.float32))
    gr = jnp.einsum("...hd,hde->...he", xh, p["w_rec"].astype(jnp.float32))
    i_t = jax.nn.sigmoid(gi.reshape(shp + (-1,)))
    r_t = jax.nn.sigmoid(gr.reshape(shp + (-1,)))
    return i_t, r_t


_RG_C = 8.0  # Griffin's fixed exponent scale


def _rg_decay(p: Tree, r_t: jax.Array) -> jax.Array:
    # a = sigmoid(lam); a_t = a ** (c * r_t)  computed in log space
    log_a = -jax.nn.softplus(-p["lam"].astype(jnp.float32))  # log sigmoid(lam)
    return jnp.exp(_RG_C * r_t * log_a)


def _causal_conv(p: Tree, x: jax.Array, state: Optional[jax.Array]):
    """Depthwise causal conv, width cw. x:(B,S,R). state:(B,cw-1,R) or None."""
    cw = p["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
              for i in range(cw))
    new_state = xp[:, -(cw - 1):]
    return out + p["conv_b"].astype(x.dtype), new_state


def rglru_seq(cfg: ArchConfig, p: Tree, x: jax.Array,
              h0: Optional[jax.Array] = None,
              conv0: Optional[jax.Array] = None):
    """Full-sequence RG-LRU block. x: (B,S,D) -> (B,S,D), final states."""
    gate = jax.nn.gelu(dense(x, p["w_gate"]))
    u = dense(x, p["w_x"])
    u, conv_state = _causal_conv(p, u, conv0)
    i_t, r_t = _rg_gates(p, u)
    a_t = _rg_decay(p, r_t)                               # (B,S,R) f32
    b_t = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 1e-8)) * (
        i_t * u.astype(jnp.float32))
    if h0 is not None:
        # fold carry-in into the first step:  h_1 = a_1 h_0 + b_1
        b_t = b_t.at[:, 0].add(a_t[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a_t, b_t), axis=1)
    out = dense((h.astype(x.dtype) * gate), p["w_out"])
    return out, h[:, -1], conv_state


def rglru_step(cfg: ArchConfig, p: Tree, x: jax.Array, h: jax.Array,
               conv_state: jax.Array):
    """Single decode step. x: (B,1,D); h: (B,R); conv_state: (B,cw-1,R)."""
    gate = jax.nn.gelu(dense(x, p["w_gate"]))
    u = dense(x, p["w_x"])
    u, conv_state = _causal_conv(p, u, conv_state)
    i_t, r_t = _rg_gates(p, u)
    a_t = _rg_decay(p, r_t)[:, 0]
    b_t = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 1e-8)) * (
        i_t[:, 0] * u[:, 0].astype(jnp.float32))
    h = a_t * h.astype(jnp.float32) + b_t
    out = dense(h[:, None].astype(x.dtype) * gate, p["w_out"])
    return out, h, conv_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell) — chunkwise GLA formulation
# ---------------------------------------------------------------------------
def init_mlstm(cfg: ArchConfig) -> Tree:
    d = cfg.d_model
    m = int(cfg.mlstm_proj_factor * d)     # value/gate width
    h = cfg.n_heads
    return {
        "w_q": P((d, d), ("embed", "heads"), "scaled"),
        "w_k": P((d, d), ("embed", "heads"), "scaled"),
        "w_v": P((d, m), ("embed", "heads"), "scaled"),
        "w_gate": P((d, m), ("embed", "heads"), "scaled"),
        "w_if": P((d, 2 * h), ("embed", None), "scaled", jnp.float32),
        "w_out": P((m, d), ("heads", "embed"), "scaled"),
    }


def _mlstm_qkvg(cfg: ArchConfig, p: Tree, x: jax.Array):
    h = cfg.n_heads
    q = dense(x, p["w_q"])
    k = dense(x, p["w_k"])
    v = dense(x, p["w_v"])
    g = jax.nn.silu(dense(x, p["w_gate"]))
    shp = x.shape[:-1]
    q = q.reshape(shp + (h, -1)).astype(jnp.float32)
    k = k.reshape(shp + (h, -1)).astype(jnp.float32) / math.sqrt(q.shape[-1])
    v = v.reshape(shp + (h, -1)).astype(jnp.float32)
    gates = (x.astype(jnp.float32) @ p["w_if"].astype(jnp.float32))
    i_raw, f_raw = jnp.split(gates.reshape(shp + (2, h)), 2, axis=-2)
    log_i = -jax.nn.softplus(-i_raw[..., 0, :])   # log sigmoid — stabilized
    log_f = -jax.nn.softplus(-f_raw[..., 0, :])
    return q, k, v, g, log_i, log_f


def mlstm_seq(cfg: ArchConfig, p: Tree, x: jax.Array,
              state: Optional[Tree] = None, chunk: int = 256):
    """Chunkwise-parallel mLSTM. x: (B,S,D).

    State: C (B,H,dk,dv), n (B,H,dk), carried across chunks via lax.scan.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    q, k, v, g, log_i, log_f = _mlstm_qkvg(cfg, p, x)
    dk, dv = q.shape[-1], v.shape[-1]
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l
    # (B,nc,L,...) views
    rs = lambda a: a.reshape((b, nc, l) + a.shape[2:])
    q_, k_, v_ = rs(q), rs(k), rs(v)
    li_, lf_ = rs(log_i), rs(log_f)

    if state is None:
        c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
    else:
        c0, n0 = state["c"].astype(jnp.float32), state["n"].astype(jnp.float32)

    def chunk_step(carry, xs):
        c, n = carry
        qc, kc, vc, lic, lfc = xs          # (B,L,H,*) / (B,L,H)
        cum_f = jnp.cumsum(lfc, axis=1)    # (B,L,H) inclusive
        # intra-chunk decay matrix  A[t,s] = exp(cum_f[t]-cum_f[s]+log_i[s])
        decay = cum_f[:, :, None, :] - cum_f[:, None, :, :] + lic[:, None, :, :]
        causal = jnp.tril(jnp.ones((l, l), bool))
        a = jnp.where(causal[None, :, :, None], jnp.exp(decay), 0.0)
        scores = jnp.einsum("blhd,bmhd->blmh", qc, kc) * a
        o_intra = jnp.einsum("blmh,bmhv->blhv", scores, vc)
        n_intra = jnp.einsum("blmh,bmhd->blhd", a, kc)
        # inter-chunk: state contribution decayed to each position
        dec_t = jnp.exp(cum_f)             # (B,L,H)
        o_inter = jnp.einsum("blhd,bhdv->blhv", qc, c) * dec_t[..., None]
        n_inter = jnp.einsum("blhd,bhd->blh", qc, n) * dec_t
        num = o_intra + o_inter
        den = jnp.abs(jnp.einsum("blhd,blhd->blh", qc, n_intra) + n_inter)
        out = num / jnp.maximum(den, 1.0)[..., None]
        # update state to end of chunk
        tail = jnp.exp(cum_f[:, -1:, :] - cum_f + lic)     # (B,L,H)
        c = c * jnp.exp(cum_f[:, -1])[:, :, None, None] + jnp.einsum(
            "blhd,blhv,blh->bhdv", kc, vc, tail)
        n = n * jnp.exp(cum_f[:, -1])[:, :, None] + jnp.einsum(
            "blhd,blh->bhd", kc, tail)
        return (c, n), out

    xs = tuple(a.swapaxes(0, 1) for a in (q_, k_, v_, li_, lf_))
    (c, n), outs = jax.lax.scan(chunk_step, (c0, n0), xs)
    o = outs.swapaxes(0, 1).reshape(b, s, h * dv).astype(x.dtype)
    y = dense(o * g, p["w_out"])
    return y, {"c": c, "n": n}


def mlstm_step(cfg: ArchConfig, p: Tree, x: jax.Array, state: Tree):
    """Single decode step. x:(B,1,D); state {c:(B,H,dk,dv), n:(B,H,dk)}."""
    q, k, v, g, log_i, log_f = _mlstm_qkvg(cfg, p, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    i_t = jnp.exp(log_i[:, 0])[..., None, None]
    f_t = jnp.exp(log_f[:, 0])[..., None, None]
    c = state["c"].astype(jnp.float32) * f_t + i_t * jnp.einsum(
        "bhd,bhv->bhdv", k, v)
    n = state["n"].astype(jnp.float32) * f_t[..., 0] + i_t[..., 0] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, c)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
    o = (num / jnp.maximum(den, 1.0)[..., None]).reshape(x.shape[0], 1, -1)
    y = dense(o.astype(x.dtype) * g, p["w_out"])
    return y, {"c": c, "n": n}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory cell, block-diagonal recurrence) + gated FFN
# ---------------------------------------------------------------------------
def init_slstm(cfg: ArchConfig) -> Tree:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    f = int(round(cfg.slstm_ff_factor * d / 128) * 128)
    return {
        "w_gates": P((d, 4 * d), ("embed", "heads"), "scaled"),
        "r_gates": P((4, h, hd, hd), (None, None, None, None), "scaled"),
        "b_gates": P((4 * d,), (None,), "zeros", jnp.float32),
        "w_up": P((d, f), ("embed", "ffn"), "scaled"),
        "w_gate": P((d, f), ("embed", "ffn"), "scaled"),
        "w_down": P((f, d), ("ffn", "embed"), "scaled"),
    }


def _slstm_cell(cfg: ArchConfig, p: Tree, zx: jax.Array, st: Tree):
    """One timestep. zx: (B,4D) pre-computed input contribution."""
    h = cfg.n_heads
    b = zx.shape[0]
    d = zx.shape[1] // 4
    hprev = st["h"]                                        # (B,D) f32
    hh = hprev.reshape(b, h, -1)
    rec = jnp.einsum("bhd,ghde->bghe", hh, p["r_gates"].astype(jnp.float32))
    rec = rec.reshape(b, 4 * d)
    pre = zx.astype(jnp.float32) + rec + p["b_gates"]
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    log_i = -jax.nn.softplus(-ii)
    log_f = -jax.nn.softplus(-fi)
    m_new = jnp.maximum(log_f + st["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + st["m"] - m_new)
    c = f_s * st["c"] + i_s * z
    n = jnp.maximum(f_s * st["n"] + i_s, 1e-6)
    h_new = o * (c / n)
    return {"h": h_new, "c": c, "n": n, "m": m_new}


def _slstm_scan_ref(cfg: ArchConfig, p_rec: Tree, zx: jax.Array,
                    state: Tree):
    """Plain autodiff reference (oracle for the custom-VJP fast path)."""
    def step(st, zt):
        st = _slstm_cell(cfg, p_rec, zt, st)
        return st, st["h"]

    state, hs = jax.lax.scan(step, state, zx.swapaxes(0, 1))
    return state, hs.swapaxes(0, 1)


# ---------------------------------------------------------------------------
# sLSTM scan with deferred weight gradient.
#
# Autodiff of a scan whose body CONTAINS a weight matmul accumulates the
# weight gradient per timestep: each backward step materializes a full
# r_gates-sized outer product and read-modify-writes the accumulator
# (~100MB of HBM traffic per step — measured to dominate the xlstm
# train_4k roofline, §Perf).  The classical RNN fix: the backward scan
# only produces the per-step pre-activation cotangents dpre_t (cheap,
# B×4D), stacked; the weight gradient is ONE einsum contracting (T, B)
# at the end:   dR = Σ_t  h_{t-1} ⊗ dpre_t,   db = Σ_t dpre_t.
# ---------------------------------------------------------------------------
def _cell_nopar(cfg: ArchConfig, pre: jax.Array, st: Tree) -> Tree:
    """_slstm_cell with the affine part (zx + R·h + b) precomputed —
    weight-free, so its VJP has no weight cotangents."""
    b = pre.shape[0]
    d = pre.shape[1] // 4
    zi, ii, fi, oi = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    log_i = -jax.nn.softplus(-ii)
    log_f = -jax.nn.softplus(-fi)
    m_new = jnp.maximum(log_f + st["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + st["m"] - m_new)
    c = f_s * st["c"] + i_s * z
    n = jnp.maximum(f_s * st["n"] + i_s, 1e-6)
    h_new = o * (c / n)
    return {"h": h_new, "c": c, "n": n, "m": m_new}


def _rec_term(cfg: ArchConfig, rgF: jax.Array, h: jax.Array):
    """R·h for the block-diagonal recurrent matrices, with the weight
    PRE-TRANSPOSED outside the scan (rgF: (h, hd, 4·hd)) so the per-step
    op is a clean invariant-operand batched matmul — XLA otherwise
    re-materializes a transposed 16MB copy of r_gates every timestep
    (measured; §Perf).  h: (B,D) -> (B,4D) in (g,h,e) layout."""
    b = h.shape[0]
    nh = rgF.shape[0]
    hh = h.reshape(b, nh, -1)
    rec = jnp.einsum("bhd,hdk->bhk", hh, rgF)        # (B,h,4·hd)
    g4 = rec.shape[-1] // (h.shape[-1] // nh)
    rec = rec.reshape(b, nh, g4, -1).transpose(0, 2, 1, 3)
    return rec.reshape(b, -1)


def _rg_fwd_layout(r_gates: jax.Array) -> jax.Array:
    """(g,h,hd,he) -> (h, hd, g·he), hoisted out of the scan."""
    g, h, d, e = r_gates.shape
    return (r_gates.astype(jnp.float32)
            .transpose(1, 2, 0, 3).reshape(h, d, g * e))


def _rg_bwd_layout(r_gates: jax.Array) -> jax.Array:
    """(g,h,hd,he) -> (h, g·he, hd) for the dh_rec contraction."""
    g, h, d, e = r_gates.shape
    return (r_gates.astype(jnp.float32)
            .transpose(1, 0, 3, 2).reshape(h, g * e, d))


def _slstm_scan(cfg: ArchConfig, p_rec: Tree, zx: jax.Array, state: Tree):
    """Public entry: f32-cast wrapper around the custom-VJP core (the
    casts' transposes restore the storage dtypes of the cotangents)."""
    p32 = jax.tree.map(lambda a: a.astype(jnp.float32), p_rec)
    return _slstm_scan_f32(cfg, p32, zx.astype(jnp.float32), state)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _slstm_scan_f32(cfg: ArchConfig, p_rec: Tree, zx: jax.Array,
                    state: Tree):
    (state, hs), _ = _slstm_scan_fwd(cfg, p_rec, zx, state)
    return state, hs


def _slstm_scan_fwd(cfg, p_rec, zx, state):
    rg = p_rec["r_gates"].astype(jnp.float32)
    rgF = _rg_fwd_layout(rg)                               # hoisted
    bg = p_rec["b_gates"].astype(jnp.float32)
    zxt = zx.swapaxes(0, 1).astype(jnp.float32)            # (T,B,4D)

    def step(st, zt):
        pre = zt + _rec_term(cfg, rgF, st["h"]) + bg
        st2 = _cell_nopar(cfg, pre, st)
        return st2, (st2, pre)

    stateN, (sts, pres) = jax.lax.scan(step, state, zxt)
    hs = sts["h"].swapaxes(0, 1)
    # residuals: per-step states shifted by one (st_{t-1} enters step t)
    prev = jax.tree.map(
        lambda s0, ss: jnp.concatenate([s0[None], ss[:-1]], 0),
        state, sts)
    return (stateN, hs), (rg, pres, prev)


def _slstm_scan_bwd(cfg, res, cots):
    rg, pres, prev = res
    rgB = _rg_bwd_layout(rg)                               # hoisted
    d_stateN, d_hs = cots
    t, b = pres.shape[0], pres.shape[1]
    g4, nh = rg.shape[0], rg.shape[1]
    d_hs_t = d_hs.swapaxes(0, 1).astype(jnp.float32)       # (T,B,D)

    def back(carry, xs):
        dst = carry                     # cotangent of st AFTER step t
        pre_t, prev_t, dh_out = xs
        dst = dict(dst)
        dst["h"] = dst["h"] + dh_out    # h_t also feeds the block output
        _, vjp = jax.vjp(lambda p, s: _cell_nopar(cfg, p, s), pre_t, prev_t)
        dpre, dprev = vjp(dst)
        # dpre also reaches h_{t-1} through the recurrent term; the
        # (h, g·e, d) weight layout is invariant (hoisted above)
        dp_h = (dpre.reshape(b, g4, nh, -1).transpose(0, 2, 1, 3)
                .reshape(b, nh, -1))                       # (B,h,g·e)
        dh_rec = jnp.einsum("bhk,hkd->bhd", dp_h, rgB).reshape(b, -1)
        dprev = dict(dprev)
        dprev["h"] = dprev["h"] + dh_rec
        return dprev, dpre

    zero_h = {k: jnp.asarray(v, jnp.float32)
              for k, v in d_stateN.items()}
    d_state0, dpres = jax.lax.scan(
        back, zero_h, (pres, prev, d_hs_t), reverse=True)

    # deferred weight gradients: ONE contraction over (T, B)
    hh_prev = prev["h"].reshape(t, b, rg.shape[1], -1)      # (T,B,h,hd)
    dp = dpres.reshape(t, b, rg.shape[0], rg.shape[1], -1)  # (T,B,g,h,hd)
    d_rg = jnp.einsum("tbhd,tbghe->ghde", hh_prev, dp)
    d_bg = jnp.sum(dpres, axis=(0, 1))
    d_zx = dpres.swapaxes(0, 1)                             # (B,T,4D)
    return {"r_gates": d_rg, "b_gates": d_bg}, d_zx, d_state0


_slstm_scan_f32.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def slstm_seq(cfg: ArchConfig, p: Tree, x: jax.Array,
              state: Optional[Tree] = None,
              par: Optional[Parallel] = None):
    b, s, d = x.shape
    zx = dense(x, p["w_gates"])                            # (B,S,4D)
    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        state = {"h": z, "c": z, "n": z + 1e-6, "m": z}
    p_rec = {"r_gates": p["r_gates"], "b_gates": p["b_gates"]}

    # Run the sequential recurrence under shard_map: under plain GSPMD the
    # backward scan all-reduces the r_gates weight-gradient partial EVERY
    # TIMESTEP (measured: 98k × 16MB collectives dominating the xlstm
    # train roofline — §Perf).  shard_map keeps the accumulation local to
    # each device and psums ONCE at the boundary; batch stays
    # data-parallel, the recurrence itself is replicated across the model
    # axis (its FLOPs are negligible next to the TP'd matmuls around it).
    from repro.models.common import _batch_axes, current_mesh
    mesh = current_mesh()
    use_sm = (mesh is not None and hasattr(mesh, "devices")
              and (par is None or par.shard_batch) and b > 1)
    if use_sm:
        from jax.sharding import PartitionSpec as PS
        baxes = _batch_axes()
        st_spec = jax.tree.map(lambda _: PS(baxes, None), state)
        from repro.models.common import shard_map_compat
        fn = shard_map_compat(
            functools.partial(_slstm_scan, cfg),
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: PS(), p_rec),
                      PS(baxes, None, None), st_spec),
            out_specs=(st_spec, PS(baxes, None, None)))
        state, hs = fn(p_rec, zx, state)
    else:
        state, hs = _slstm_scan(cfg, p_rec, zx, state)
    hs = hs.astype(x.dtype)                                # (B,S,D)
    up = jax.nn.gelu(dense(hs, p["w_up"])) * dense(hs, p["w_gate"])
    return dense(up, p["w_down"]), state


def slstm_step(cfg: ArchConfig, p: Tree, x: jax.Array, state: Tree):
    zx = dense(x, p["w_gates"])[:, 0]
    state = _slstm_cell(cfg, p, zx, state)
    hs = state["h"][:, None].astype(x.dtype)
    up = jax.nn.gelu(dense(hs, p["w_up"])) * dense(hs, p["w_gate"])
    return dense(up, p["w_down"]), state


def init_recurrent_state(cfg: ArchConfig, kind: str, batch: int) -> Dict[str, P]:
    """Abstract decode-state declaration for one layer of `kind`."""
    d = cfg.d_model
    if kind == "rglru":
        r = cfg.rnn_width or d
        return {"h": P((batch, r), ("batch", "rnn"), "zeros", jnp.float32),
                "conv": P((batch, cfg.conv_width - 1, r),
                          ("batch", None, "rnn"), "zeros")}
    if kind == "mlstm":
        h = cfg.n_heads
        dk = d // h
        dv = int(cfg.mlstm_proj_factor * d) // h
        return {"c": P((batch, h, dk, dv), ("batch", None, None, None),
                       "zeros", jnp.float32),
                "n": P((batch, h, dk), ("batch", None, None), "zeros",
                       jnp.float32)}
    if kind == "slstm":
        return {k: P((batch, d), ("batch", None), "zeros", jnp.float32)
                for k in ("h", "c", "n", "m")}
    raise ValueError(kind)
