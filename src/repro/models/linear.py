"""Single matmul entry point for the whole model zoo.

``dense(x, w)`` accepts either a plain (K, N) array or any *quantized
weight object* exposing ``__matmul_x__(x)`` (duck-typed; see
``repro.core.qlinear.QLinear``).  This is the seam through which PTQ1.61
(and every baseline quantizer) plugs into serving without touching model
code.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense(x: jax.Array, w, bias: Optional[jax.Array] = None) -> jax.Array:
    if hasattr(w, "__matmul_x__"):
        y = w.__matmul_x__(x)
    else:
        y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def expert_dense(x: jax.Array, w) -> jax.Array:
    """Per-expert batched matmul: x (E,C,K) @ w (E,K,N) -> (E,C,N)."""
    if hasattr(w, "__expert_matmul__"):
        return w.__expert_matmul__(x)
    return jnp.einsum("eck,ekn->ecn", x, w.astype(x.dtype))
