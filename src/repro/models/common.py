"""Shared model-side helpers: run-time parallelism knobs and sharding hints."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


@dataclass(frozen=True)
class Parallel:
    """Parallelism knobs the *model code* needs to know about.

    The full mesh/rule mapping lives in ``repro.distributed.sharding``; the
    model only needs the tensor-parallel degree (to pre-replicate KV heads)
    and whether to emit sequence-parallel sharding hints.
    """

    tp: int = 1                 # size of the "model" mesh axis
    dp: int = 1                 # size of the "data" (* pod) axes
    fsdp: bool = False          # ZeRO-3: shard params' embed dim over data
    sp: bool = True             # sequence-parallel activation constraints
    microbatches: int = 1       # gradient-accumulation chunks inside train_step
    remat: bool = True          # activation checkpointing on the layer scan
    attn_chunk: int = 1024      # flash-style KV chunking threshold/size
    shard_batch: bool = True    # False when global batch < dp (long_500k)
    decode_unroll: bool = False # unroll the decode layer loop: KV caches
                                # update in place (slot writes) instead of
                                # scan-carry slice round-trips (§Perf)

    def kv_heads_run(self, n_kv: int, n_q: Optional[int] = None) -> int:
        """Megatron-style KV-head replication for tensor parallelism.

        Replicate KV heads toward the TP degree so the KV projections and
        cache shard over "model", subject to the GQA constraint that the
        run-time KV count must divide the query-head count (the attention
        kernel reshapes q to (…, hkv, rep, dh)).  For archs whose head
        counts don't divide the TP degree (phi4 24H, llava 56H,
        recurrentgemma 10H) we return the largest valid count ≤ tp and let
        GSPMD pad the uneven shard — correct, with the padding cost
        visible in the §Roofline report rather than hidden.
        """
        if self.tp <= n_kv:
            return n_kv
        best = n_kv
        if n_q is None:
            # no GQA constraint available: largest multiple of n_kv ≤ tp
            return (self.tp // n_kv) * n_kv
        for cand in range(n_kv, self.tp + 1, n_kv):
            if n_q % cand == 0:
                best = cand
        return best


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """shard_map across jax versions: `jax.shard_map(..., check_vma=)` on
    new jax, `jax.experimental.shard_map.shard_map(..., check_rep=)` on
    0.4.x.  Replication checking is off in both (the MoE/pipeline bodies
    use collectives the checker can't type)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def current_mesh():
    """The ambient mesh during tracing, or None.

    Checks the new abstract-mesh context first, then the legacy
    ``with mesh:`` thread-resources context (which jax.jit +
    with_sharding_constraint(PartitionSpec) still uses) — the abstract
    mesh alone is empty under ``with mesh:``, which silently no-ops every
    activation hint (found via the dry-run roofline; EXPERIMENTS.md §Perf).
    """
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:          # jax >= 0.5; absent on 0.4.x
        am = get_am()
        if am is not None and not am.empty:
            return am
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def in_mesh() -> bool:
    """True when tracing under a non-trivial device mesh."""
    m = current_mesh()
    return m is not None and m.devices.size > 1 if hasattr(m, "devices") \
        else m is not None


def hint(x: jax.Array, *axes) -> jax.Array:
    """Sharding-constraint that degrades to a no-op off-mesh (smoke tests)."""
    if not in_mesh():
        return x
    return jax.lax.with_sharding_constraint(x, PS(*axes))


def hint_act(x: jax.Array, par) -> jax.Array:
    """Residual-stream activation hint.

    (batch, seq, d_model): batch over data(+pod), and — when sequence
    parallelism is on — seq over the model axis (otherwise the residual
    stream would be replicated across TP ranks between blocks).
    """
    if not in_mesh():
        return x
    batch_axes = _batch_axes() if par.shard_batch and x.shape[0] > 1 else None
    if x.ndim == 3 and par.sp and x.shape[1] > 1:
        return jax.lax.with_sharding_constraint(x, PS(batch_axes, "model", None))
    if x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, PS(batch_axes, None, None))
    return jax.lax.with_sharding_constraint(x, PS(batch_axes, None))


def _batch_axes():
    m = current_mesh()
    names = m.axis_names if m is not None else ()
    return ("pod", "data") if "pod" in names else "data"


def batch_spec(*rest) -> PS:
    """PartitionSpec with the batch dim over data(+pod) and given tail axes."""
    return PS(_batch_axes(), *rest)
