"""Transformer assembly: superblock stage scans for all six block kinds,
with full-sequence (train/prefill), cache-prefill and single-step decode
paths, encoder–decoder support, and frontend stubs (vision/audio).

Depth is always `jax.lax.scan` over stacked per-layer parameters so the
lowered HLO is depth-independent (critical for the 512-device dry-run).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Stage
from repro.models import layers as L
from repro.models import recurrent as R
from repro.models.common import Parallel, hint_act
from repro.models.linear import dense
from repro.models.param import P, is_leaf, tree_map_params

Tree = Any


# ---------------------------------------------------------------------------
# Per-block parameter declarations
# ---------------------------------------------------------------------------
def init_block(cfg: ArchConfig, par: Parallel, kind: str,
               cross: bool = False) -> Tree:
    p: Dict[str, Tree] = {}
    if kind in ("dense", "moe", "local"):
        p["ln1"] = L.init_norm(cfg)
        p["attn"] = L.init_attention(cfg, par)
        p["ln2"] = L.init_norm(cfg)
        p["mlp"] = L.init_moe(cfg) if kind == "moe" else L.init_mlp(cfg)
    elif kind == "rglru":
        p["ln1"] = L.init_norm(cfg)
        p["rec"] = R.init_rglru(cfg)
        p["ln2"] = L.init_norm(cfg)
        p["mlp"] = L.init_mlp(cfg)
    elif kind == "mlstm":
        p["ln1"] = L.init_norm(cfg)
        p["cell"] = R.init_mlstm(cfg)
    elif kind == "slstm":
        p["ln1"] = L.init_norm(cfg)
        p["cell"] = R.init_slstm(cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["ln_x"] = L.init_norm(cfg)
        p["xattn"] = L.init_attention(cfg, par, cross=True)
    return p


def stack_p(tree: Tree, n: int) -> Tree:
    """Prepend a scanned `layers` dim to every P leaf."""
    return tree_map_params(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.init, p.dtype), tree)


# ---------------------------------------------------------------------------
# Decode fast path: N-fused projection layouts (QKV, gate+up)
# ---------------------------------------------------------------------------
def _fusable(d, names) -> bool:
    return d is not None and all(isinstance(d.get(k), jax.Array)
                                 for k in names)


def fuse_block_params(p: Tree) -> Tree:
    """Fuse one block's same-input projections along N for decode.

    ``wq``/``wk``/``wv`` become one ``wqkv`` :class:`QLinearGroup` and an
    MLP's ``wg``/``wu`` become ``wgu`` — each transformer block then
    issues 2 projection matmuls instead of 5.  MoE expert weights fuse
    the same way along their last (N) axis: the stacked ``(E, K, F)``
    gate/up pair becomes one ``(E, K, 2F)`` group served by a single
    ``expert_dense`` batched matmul (and, quantized, one per-expert
    activation gather).  Concatenating fp arrays is mathematically
    exact; already-quantized (QLinear) leaves are left unfused because
    post-hoc fusion cannot reconcile their per-projection permutations —
    quantize with ``quantize_params_data_free(..., fuse=True)`` to get
    fused packed layouts.  Cross-attention keeps the per-projection
    path.
    """
    from repro.core.qlinear import QLinearGroup
    p = dict(p)
    attn = p.get("attn")
    if _fusable(attn, ("wq", "wk", "wv")):
        attn = dict(attn)
        ws = [attn.pop(k) for k in ("wq", "wk", "wv")]
        attn["wqkv"] = QLinearGroup(jnp.concatenate(ws, axis=-1),
                                    tuple(int(w.shape[-1]) for w in ws))
        p["attn"] = attn
    mlp = p.get("mlp")
    if mlp is not None and _fusable(mlp, ("wg", "wu")):
        mlp = dict(mlp)
        ws = [mlp.pop(k) for k in ("wg", "wu")]
        mlp["wgu"] = QLinearGroup(jnp.concatenate(ws, axis=-1),
                                  tuple(int(w.shape[-1]) for w in ws))
        p["mlp"] = mlp
    return p


def unfuse_block_params(p: Tree) -> Tree:
    """Inverse of :func:`fuse_block_params`: rebuild per-projection
    weights as unfused VIEWS over the same (fp or packed) data — the
    oracle the fused path is tested against."""
    p = dict(p)
    attn = p.get("attn")
    if attn is not None and "wqkv" in attn:
        attn = dict(attn)
        g = attn.pop("wqkv")
        attn["wq"], attn["wk"], attn["wv"] = g.members()
        p["attn"] = attn
    mlp = p.get("mlp")
    if mlp is not None and "wgu" in mlp:
        mlp = dict(mlp)
        g = mlp.pop("wgu")
        mlp["wg"], mlp["wu"] = g.members()
        p["mlp"] = mlp
    return p


def fuse_params_for_decode(params: Tree) -> Tree:
    """Apply :func:`fuse_block_params` across every stage's (stacked)
    block trees.  Stacked (L, K, N) leaves concatenate along N exactly
    like 2-D ones, so the fused groups slice cleanly under scan."""
    new = dict(params)
    new["stages"] = [tuple(fuse_block_params(bp) for bp in sp)
                     for sp in params["stages"]]
    return new


def unfuse_params_for_oracle(params: Tree) -> Tree:
    new = dict(params)
    new["stages"] = [tuple(unfuse_block_params(bp) for bp in sp)
                     for sp in params["stages"]]
    return new


def init_stage(cfg: ArchConfig, par: Parallel, stage: Stage,
               cross: bool = False) -> Tuple[Tree, ...]:
    return tuple(stack_p(init_block(cfg, par, k, cross), stage.repeats)
                 for k in stage.pattern)


def _kind_window(cfg: ArchConfig, kind: str, max_seq: int) -> Optional[int]:
    if kind == "local":
        return cfg.local_window
    if kind in ("dense", "moe"):
        return cfg.attn_window
    return None


def _cache_window(cfg: ArchConfig, kind: str, max_seq: int) -> int:
    w = _kind_window(cfg, kind, max_seq)
    return min(w, max_seq) if w is not None else max_seq


# ---------------------------------------------------------------------------
# Block applications — full sequence
# ---------------------------------------------------------------------------
def block_full(cfg: ArchConfig, par: Parallel, kind: str, p: Tree,
               x: jax.Array, positions: jax.Array, *, causal: bool,
               enc_out: Optional[jax.Array] = None,
               enc_pos: Optional[jax.Array] = None,
               aux: Optional[jax.Array] = None):
    """One block over a whole sequence. Returns (x, aux)."""
    if kind in ("dense", "moe", "local"):
        w = _kind_window(cfg, kind, x.shape[1])
        h = L.attention_full(cfg, par, p["attn"], L.apply_norm(cfg, p["ln1"], x),
                             positions, causal=causal, window=w)
        x = x + h
        if "xattn" in p:
            h = L.attention_full(cfg, par, p["xattn"],
                                 L.apply_norm(cfg, p["ln_x"], x), positions,
                                 causal=False, use_rope=False, xkv=enc_out,
                                 kv_positions=enc_pos)
            x = x + h
        z = L.apply_norm(cfg, p["ln2"], x)
        if kind == "moe":
            h = L.apply_moe(cfg, p["mlp"], z, par)
            if aux is not None:
                aux = aux + L.moe_aux_loss(cfg, z, p["mlp"]["router"])
        else:
            h = L.apply_mlp(cfg, p["mlp"], z)
        x = x + h
    elif kind == "rglru":
        h, _, _ = R.rglru_seq(cfg, p["rec"], L.apply_norm(cfg, p["ln1"], x))
        x = x + h
        x = x + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    elif kind == "mlstm":
        h, _ = R.mlstm_seq(cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x))
        x = x + h
    elif kind == "slstm":
        h, _ = R.slstm_seq(cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x),
                           par=par)
        x = x + h
    else:
        raise ValueError(kind)
    return hint_act(x, par), aux


def stage_full(cfg: ArchConfig, par: Parallel, stage: Stage, sparams: Tree,
               x: jax.Array, positions: jax.Array, *, causal: bool,
               enc_out=None, enc_pos=None, remat: bool = False):
    """Scan a stage over its superblocks (training / eval forward)."""

    def body(carry, lp):
        x, aux = carry
        for i, kind in enumerate(stage.pattern):
            x, aux = block_full(cfg, par, kind, lp[i], x, positions,
                                causal=causal, enc_out=enc_out,
                                enc_pos=enc_pos, aux=aux)
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), sparams)
    return x, aux


# ---------------------------------------------------------------------------
# Prefill: full sequence + build decode caches
# ---------------------------------------------------------------------------
def block_prefill(cfg: ArchConfig, par: Parallel, kind: str, p: Tree,
                  x: jax.Array, positions: jax.Array, max_seq: int,
                  enc_out=None, enc_pos=None):
    """Returns (x, cache) for one block."""
    if kind in ("dense", "moe", "local"):
        w = _kind_window(cfg, kind, x.shape[1])
        z = L.apply_norm(cfg, p["ln1"], x)
        h, cache = L.attention_full(cfg, par, p["attn"], z, positions,
                                    causal=True, window=w,
                                    cache_window=_cache_window(cfg, kind, max_seq))
        x = x + h
        if "xattn" in p:
            zx = L.apply_norm(cfg, p["ln_x"], x)
            h = L.attention_full(cfg, par, p["xattn"], zx, positions,
                                 causal=False, use_rope=False, xkv=enc_out,
                                 kv_positions=enc_pos)
            x = x + h
            # cross-attn K/V are static over decode: cache them once
            q, k, v = L._project_qkv(cfg, par, p["xattn"], zx, enc_out,
                                     positions, enc_pos, False)
            cache = {"self": cache, "xk": k, "xv": v}
        z = L.apply_norm(cfg, p["ln2"], x)
        h = L.apply_moe(cfg, p["mlp"], z, par) if kind == "moe" else \
            L.apply_mlp(cfg, p["mlp"], z)
        x = x + h
    elif kind == "rglru":
        h, hN, conv = R.rglru_seq(cfg, p["rec"], L.apply_norm(cfg, p["ln1"], x))
        cache = {"h": hN, "conv": conv}
        x = x + h
        x = x + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    elif kind == "mlstm":
        h, cache = R.mlstm_seq(cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x))
        x = x + h
    elif kind == "slstm":
        h, cache = R.slstm_seq(cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x),
                               par=par)
        x = x + h
    else:
        raise ValueError(kind)
    return hint_act(x, par), cache


def stage_prefill(cfg: ArchConfig, par: Parallel, stage: Stage, sparams: Tree,
                  x: jax.Array, positions: jax.Array, max_seq: int,
                  enc_out=None, enc_pos=None):
    def body(x, lp):
        caches = []
        for i, kind in enumerate(stage.pattern):
            x, c = block_prefill(cfg, par, kind, lp[i], x, positions, max_seq,
                                 enc_out, enc_pos)
            caches.append(c)
        return x, tuple(caches)

    x, caches = jax.lax.scan(body, x, sparams)
    return x, caches          # caches: tuple per position, stacked (repeats,)


# ---------------------------------------------------------------------------
# Decode: single step, carry per-layer state
# ---------------------------------------------------------------------------
def block_step(cfg: ArchConfig, par: Parallel, kind: str, p: Tree,
               x: jax.Array, pos: jax.Array, cache: Tree, max_seq: int,
               layer=None):
    if kind in ("dense", "moe", "local"):
        w = _kind_window(cfg, kind, max_seq)
        self_cache = cache["self"] if "xattn" in p else cache
        h, new_self = L.attention_decode(
            cfg, par, p["attn"], L.apply_norm(cfg, p["ln1"], x), pos,
            self_cache, window=w, layer=layer)
        x = x + h
        if "xattn" in p:
            zx = L.apply_norm(cfg, p["ln_x"], x)
            hq = cfg.n_heads
            dh = cfg.head_dim_
            q = dense(zx, p["xattn"]["wq"]).reshape(x.shape[0], 1, hq, dh)
            xk = cache["xk"] if layer is None else cache["xk"][layer]
            xv = cache["xv"] if layer is None else cache["xv"][layer]
            mask = jnp.ones((x.shape[0], 1, xk.shape[1]), bool)
            o = L._attend(q, xk, xv, mask, cfg.logit_softcap)
            x = x + dense(o.astype(x.dtype).reshape(x.shape[0], 1, -1),
                          p["xattn"]["wo"])
            new_cache = {"self": new_self, "xk": cache["xk"],
                         "xv": cache["xv"]}
        else:
            new_cache = new_self
        z = L.apply_norm(cfg, p["ln2"], x)
        h = L.apply_moe(cfg, p["mlp"], z, par) if kind == "moe" else \
            L.apply_mlp(cfg, p["mlp"], z)
        x = x + h
    elif kind == "rglru":
        c = cache if layer is None else jax.tree.map(lambda a: a[layer], cache)
        h, hN, conv = R.rglru_step(cfg, p["rec"], L.apply_norm(cfg, p["ln1"], x),
                                   c["h"], c["conv"])
        new_cache = {"h": hN, "conv": conv}
        if layer is not None:
            new_cache = jax.tree.map(lambda full, new: full.at[layer].set(new),
                                     cache, new_cache)
        x = x + h
        x = x + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    elif kind == "mlstm":
        c = cache if layer is None else jax.tree.map(lambda a: a[layer], cache)
        h, new_cache = R.mlstm_step(cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x),
                                    c)
        if layer is not None:
            new_cache = jax.tree.map(lambda full, new: full.at[layer].set(new),
                                     cache, new_cache)
        x = x + h
    elif kind == "slstm":
        c = cache if layer is None else jax.tree.map(lambda a: a[layer], cache)
        h, new_cache = R.slstm_step(cfg, p["cell"], L.apply_norm(cfg, p["ln1"], x),
                                    c)
        if layer is not None:
            new_cache = jax.tree.map(lambda full, new: full.at[layer].set(new),
                                     cache, new_cache)
        x = x + h
    else:
        raise ValueError(kind)
    return hint_act(x, par), new_cache


def stage_step(cfg: ArchConfig, par: Parallel, stage: Stage, sparams: Tree,
               x: jax.Array, pos: jax.Array, caches: Tree, max_seq: int):
    if par.decode_unroll:
        # Unrolled decode: each layer's cache is addressed directly in the
        # stacked buffer, so the update is an in-place slot write instead
        # of a scan-carry dynamic-slice/update round trip over the whole
        # (B, W, H, dh) window — ~2× less decode HBM traffic (§Perf).
        cur = list(caches)          # per-pattern-position stacked trees
        for layer in range(stage.repeats):
            lp = jax.tree.map(lambda a: a[layer], sparams)
            for i, kind in enumerate(stage.pattern):
                x, cur[i] = block_step(cfg, par, kind, lp[i], x, pos,
                                       cur[i], max_seq, layer=layer)
        return x, tuple(cur)

    def body(x, xs):
        lp, cs = xs
        new = []
        for i, kind in enumerate(stage.pattern):
            x, c = block_step(cfg, par, kind, lp[i], x, pos, cs[i], max_seq)
            new.append(c)
        return x, tuple(new)

    x, new_caches = jax.lax.scan(body, x, (sparams, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Paged decode: block-table addressed KV pages (serving runtime)
# ---------------------------------------------------------------------------
ATTN_KINDS = ("dense", "moe", "local")


def block_step_paged(cfg: ArchConfig, par: Parallel, kind: str, p: Tree,
                     x: jax.Array, pos: jax.Array, cache: Tree,
                     block_tables: jax.Array, context_lens, max_seq: int,
                     layer: int, use_kernel: bool = True):
    """Paged variant of :func:`block_step` for attention blocks; recurrent
    blocks carry O(1) per-slot state and keep the dense (unrolled) path."""
    if kind in ATTN_KINDS:
        w = _kind_window(cfg, kind, max_seq)
        h, new_cache = L.attention_decode_paged(
            cfg, par, p["attn"], L.apply_norm(cfg, p["ln1"], x), pos,
            cache, block_tables, lengths=context_lens, window=w,
            layer=layer, use_kernel=use_kernel)
        x = x + h
        z = L.apply_norm(cfg, p["ln2"], x)
        h = L.apply_moe(cfg, p["mlp"], z, par) if kind == "moe" else \
            L.apply_mlp(cfg, p["mlp"], z)
        return hint_act(x + h, par), new_cache
    return block_step(cfg, par, kind, p, x, pos, cache, max_seq, layer=layer)


def stage_step_paged(cfg: ArchConfig, par: Parallel, stage: Stage,
                     sparams: Tree, x: jax.Array, pos: jax.Array,
                     caches: Tree, block_tables: jax.Array,
                     context_lens=None, max_seq: int = 0,
                     use_kernel: bool = True):
    """Always unrolled over layers: each layer's page writes are in-place
    slot scatters addressed into the stacked pool; a scan would round-trip
    the whole (L, P, ps, H, dh) pool through the carry every layer.

    Fully-inactive ticks (every block-table row -1, i.e. no slot owns a
    page) short-circuit via ``lax.cond``: the whole layer walk — QKV
    projections, page scatters, attention, MLPs — is skipped on device
    and x/caches pass through untouched.  Per-row inactivity inside a
    live batch is handled downstream (the kernel zero-fills rows with
    ``context_lens == 0``; the XLA path masks their pages)."""

    def walk(args):
        x, caches = args
        cur = list(caches)
        for layer in range(stage.repeats):
            lp = jax.tree.map(lambda a: a[layer], sparams)
            for i, kind in enumerate(stage.pattern):
                x, cur[i] = block_step_paged(cfg, par, kind, lp[i], x, pos,
                                             cur[i], block_tables,
                                             context_lens, max_seq, layer,
                                             use_kernel)
        return x, tuple(cur)

    return jax.lax.cond(jnp.any(block_tables >= 0), walk,
                        lambda args: args, (x, caches))


def block_prefill_step_paged(cfg: ArchConfig, par: Parallel, kind: str,
                             p: Tree, x: jax.Array, positions: jax.Array,
                             cache: Tree, bt_read: jax.Array,
                             bt_write: jax.Array, start, length,
                             max_seq: int, layer: int,
                             use_kernel: bool = True):
    """One block of one CHUNK of paged prefill (attention kinds only —
    recurrent blocks carry sequential state across chunks, which the
    chunked path does not thread; the engine keeps whole-prompt prefill
    for hybrid stages)."""
    if kind not in ATTN_KINDS:
        raise NotImplementedError(
            f"chunked paged prefill supports attention blocks only, "
            f"got {kind!r} — serve hybrid/recurrent stages with the "
            f"whole-prompt prefill path")
    w = _kind_window(cfg, kind, max_seq)
    h, new_cache = L.attention_prefill_paged(
        cfg, par, p["attn"], L.apply_norm(cfg, p["ln1"], x), positions,
        cache, bt_read, bt_write, start, length, layer=layer, window=w,
        use_kernel=use_kernel)
    x = x + h
    z = L.apply_norm(cfg, p["ln2"], x)
    h = L.apply_moe(cfg, p["mlp"], z, par) if kind == "moe" else \
        L.apply_mlp(cfg, p["mlp"], z)
    return hint_act(x + h, par), new_cache


def stage_prefill_step_paged(cfg: ArchConfig, par: Parallel, stage: Stage,
                             sparams: Tree, x: jax.Array,
                             positions: jax.Array, caches: Tree,
                             bt_read: jax.Array, bt_write: jax.Array,
                             start, length, max_seq: int = 0,
                             use_kernel: bool = True):
    """Chunk-prefill walk over a stage: unrolled over layers exactly
    like :func:`stage_step_paged`, so each layer's fused scatter+attend
    updates the stacked pool in place instead of round-tripping it
    through a scan carry."""
    cur = list(caches)
    for layer in range(stage.repeats):
        lp = jax.tree.map(lambda a: a[layer], sparams)
        for i, kind in enumerate(stage.pattern):
            x, cur[i] = block_prefill_step_paged(
                cfg, par, kind, lp[i], x, positions, cur[i], bt_read,
                bt_write, start, length, max_seq, layer, use_kernel)
    return x, tuple(cur)


def stage_splice_paged(cfg: ArchConfig, stage: Stage, pool_stage: Tree,
                       cache1_stage: Tree, slot, bt_row: jax.Array) -> Tree:
    """Splice one request's prefill caches into the paged pools.

    Attention caches scatter by absolute token position into the pages of
    ``bt_row``; recurrent states splice into decode-batch slot ``slot``
    exactly as the contiguous path does."""
    out = []
    for i, kind in enumerate(stage.pattern):
        pool_i, c1 = pool_stage[i], cache1_stage[i]
        if kind in ATTN_KINDS:
            out.append(L.scatter_pages(pool_i, c1["k"][:, 0], c1["v"][:, 0],
                                       c1["p"][0, 0], bt_row))
        else:
            out.append(jax.tree.map(
                lambda full, new: full.at[:, slot].set(new[:, 0]),
                pool_i, c1))
    return tuple(out)


def stage_copy_pages(cfg: ArchConfig, stage: Stage, pool_stage: Tree,
                     src, dst) -> Tree:
    """COW page copies for one stage: attention pools copy ``src`` page
    rows onto ``dst`` across all layers at once; recurrent per-slot
    state passes through untouched (it owns no pages)."""
    out = []
    for i, kind in enumerate(stage.pattern):
        pool_i = pool_stage[i]
        if kind in ATTN_KINDS:
            out.append({"k": pool_i["k"].at[:, dst].set(pool_i["k"][:, src]),
                        "v": pool_i["v"].at[:, dst].set(pool_i["v"][:, src])})
        else:
            out.append(pool_i)
    return tuple(out)


def init_stage_cache_paged(cfg: ArchConfig, par: Parallel, stage: Stage,
                           n_slots: int, num_pages: int,
                           page_size: int, dtype=None) -> Tree:
    """Paged mirror of :func:`init_stage_cache`: attention blocks share
    the (num_pages, page_size) pool; recurrent blocks keep per-slot
    state at the decode batch size."""
    per_pos = []
    for kind in stage.pattern:
        if kind in ATTN_KINDS:
            c = L.make_paged_cache(cfg, par, num_pages, page_size,
                                   stage.repeats,
                                   **({} if dtype is None
                                      else {"dtype": dtype}))
        else:
            c = stack_p(R.init_recurrent_state(cfg, kind, n_slots),
                        stage.repeats)
        per_pos.append(c)
    return tuple(per_pos)


# ---------------------------------------------------------------------------
# Decode-cache declarations (abstract P trees, mirror stage_prefill output)
# ---------------------------------------------------------------------------
def init_stage_cache(cfg: ArchConfig, par: Parallel, stage: Stage,
                     batch: int, max_seq: int, enc_len: int = 0) -> Tree:
    per_pos = []
    for kind in stage.pattern:
        if kind in ("dense", "moe", "local"):
            w = _cache_window(cfg, kind, max_seq)
            hkv = par.kv_heads_run(cfg.n_kv_heads, cfg.n_heads)
            # KV heads shard over "model" when they fill/divide it evenly;
            # otherwise shard the context window instead (pjit boundary
            # shardings must divide exactly — phi4 24H / llava 56H / rg 10H)
            tp = max(par.tp, 1)
            if hkv % tp == 0:
                kv_axes = ("batch", None, "kv_heads", None)
            elif w % tp == 0:
                kv_axes = ("batch", "ctx", "kv_heads", None)
            else:
                kv_axes = ("batch", None, None, None)   # replicate (tiny)
            c = {
                "k": P((batch, w, hkv, cfg.head_dim_), kv_axes, "zeros"),
                "v": P((batch, w, hkv, cfg.head_dim_), kv_axes, "zeros"),
                "p": P((batch, w), ("batch", None), "neg_ones", jnp.int32),
            }
            if cfg.enc_dec and enc_len:
                xa = (("batch", None, "kv_heads", None)
                      if hkv % tp == 0 else
                      (("batch", "ctx", "kv_heads", None)
                       if enc_len % tp == 0 else
                       ("batch", None, None, None)))
                c = {"self": c,
                     "xk": P((batch, enc_len, hkv, cfg.head_dim_), xa,
                             "zeros"),
                     "xv": P((batch, enc_len, hkv, cfg.head_dim_), xa,
                             "zeros")}
        else:
            c = R.init_recurrent_state(cfg, kind, batch)
        per_pos.append(stack_p(c, stage.repeats))
    return tuple(per_pos)
