"""Model facade: parameter declaration, loss, prefill and decode entry
points for every architecture family (decoder-only LM, VLM/audio stubs,
encoder–decoder).

All public functions are pure and jit/pjit-friendly; the launchers wrap
them with shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import Parallel, hint_act
from repro.models.linear import dense
from repro.models.param import P, abstractify, count_params, materialize

Tree = Any

XENT_CHUNK = 512


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------
def declare_params(cfg: ArchConfig, par: Parallel) -> Tree:
    d, v = cfg.d_model, cfg.vocab_padded
    p: Dict[str, Tree] = {
        "embed": P((v, d), ("vocab", "embed"), "normal"),
        "stages": [T.init_stage(cfg, par, s, cross=cfg.enc_dec)
                   for s in cfg.stages],
        "final_norm": L.init_norm(cfg),
    }
    if not cfg.tied_embeddings:
        p["lm_head"] = P((d, v), ("embed", "vocab"), "scaled")
    if cfg.enc_dec:
        from repro.configs.base import Stage
        enc_stage = Stage(("dense",), cfg.n_enc_layers)
        p["enc"] = {
            "stages": [T.init_stage(cfg, par, enc_stage)],
            "final_norm": L.init_norm(cfg),
        }
    return p


def init_params(cfg: ArchConfig, par: Parallel, key) -> Tree:
    return materialize(declare_params(cfg, par), key)


def abstract_params(cfg: ArchConfig, par: Parallel) -> Tree:
    return abstractify(declare_params(cfg, par))


def n_params(cfg: ArchConfig, par: Optional[Parallel] = None) -> int:
    return count_params(declare_params(cfg, par or Parallel()))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embed_tokens(cfg: ArchConfig, params: Tree, tokens: jax.Array) -> jax.Array:
    e = params["embed"]
    if hasattr(e, "__gather_rows__"):
        return e.__gather_rows__(tokens)
    return jnp.take(e, tokens, axis=0)


def _head_weight(cfg: ArchConfig, params: Tree):
    if cfg.tied_embeddings:
        e = params["embed"]
        return e.T if isinstance(e, jax.Array) else e.transpose()
    return params["lm_head"]


def _mask_pad(cfg: ArchConfig, logits: jax.Array) -> jax.Array:
    if cfg.vocab_padded == cfg.vocab:
        return logits
    pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
    return jnp.where(pad_mask, logits, jnp.finfo(jnp.float32).min)


def logits_fn(cfg: ArchConfig, params: Tree, x: jax.Array) -> jax.Array:
    x = L.apply_norm(cfg, params["final_norm"], x)
    return _mask_pad(cfg, dense(x, _head_weight(cfg, params)))


def softmax_xent_chunked(cfg: ArchConfig, params: Tree, x: jax.Array,
                         targets: jax.Array, chunk: int = XENT_CHUNK):
    """Cross entropy without materializing (B,S,V) logits.

    Scans seq chunks; each chunk's logits are recomputed in the backward
    pass (jax.checkpoint) so peak memory stays at (B,chunk,V/shards).
    targets < 0 are masked out.
    """
    b, s, d = x.shape
    x = L.apply_norm(cfg, params["final_norm"], x)
    w = _head_weight(cfg, params)
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # fallback for odd smoke shapes
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)
    tc = targets.reshape(b, nc, chunk).swapaxes(0, 1)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_loss(carry, xs):
        xx, tt = xs
        logits = _mask_pad(cfg, dense(xx, w).astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, tt.clip(0)[..., None], axis=-1)[..., 0]
        mask = (tt >= 0).astype(jnp.float32)
        loss, cnt = carry
        return (loss + jnp.sum((lse - picked) * mask), cnt + jnp.sum(mask)), None

    (loss, cnt), _ = jax.lax.scan(chunk_loss, (jnp.zeros(()), jnp.zeros(())),
                                  (xc, tc))
    return loss / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def _backbone_inputs(cfg: ArchConfig, params: Tree, batch: Dict[str, jax.Array]):
    """Token embedding + frontend-stub splicing (vision prefix / audio enc)."""
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        ft = batch["vision_embeds"].shape[1]
        x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype),
                             x[:, ft:]], axis=1)
    bsz, s = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))
    return x, positions


def encode(cfg: ArchConfig, par: Parallel, params: Tree,
           frames: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Audio/enc-dec encoder over precomputed frame embeddings (stub
    frontend): frames (B, S_enc, D) -> (enc_out, enc_positions)."""
    from repro.configs.base import Stage
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = hint_act(frames, par)
    enc_stage = Stage(("dense",), cfg.n_enc_layers)
    for sp in params["enc"]["stages"]:
        x, _ = T.stage_full(cfg, par, enc_stage, sp, x, pos, causal=False)
    return L.apply_norm(cfg, params["enc"]["final_norm"], x), pos


def forward_loss(cfg: ArchConfig, par: Parallel, params: Tree,
                 batch: Dict[str, jax.Array]) -> jax.Array:
    """Causal-LM loss (plus MoE aux).  batch: tokens (B,S), targets (B,S),
    optional vision_embeds (B,ft,D) / frames (B,S_enc,D)."""
    x, positions = _backbone_inputs(cfg, params, batch)
    x = hint_act(x, par)
    enc_out = enc_pos = None
    if cfg.enc_dec:
        enc_out, enc_pos = encode(cfg, par, params, batch["frames"])
    aux = jnp.zeros((), jnp.float32)
    for stage, sp in zip(cfg.stages, params["stages"]):
        x, a = T.stage_full(cfg, par, stage, sp, x, positions, causal=True,
                            enc_out=enc_out, enc_pos=enc_pos, remat=par.remat)
        aux = aux + a
    loss = softmax_xent_chunked(cfg, params, x, batch["targets"])
    return loss + 0.01 * aux


def prefill(cfg: ArchConfig, par: Parallel, params: Tree,
            batch: Dict[str, jax.Array], max_seq: int):
    """Full-sequence prefill -> (last-token logits, caches)."""
    x, positions = _backbone_inputs(cfg, params, batch)
    x = hint_act(x, par)
    enc_out = enc_pos = None
    if cfg.enc_dec:
        enc_out, enc_pos = encode(cfg, par, params, batch["frames"])
    caches = []
    for stage, sp in zip(cfg.stages, params["stages"]):
        x, c = T.stage_prefill(cfg, par, stage, sp, x, positions, max_seq,
                               enc_out=enc_out, enc_pos=enc_pos)
        caches.append(c)
    logits = logits_fn(cfg, params, x[:, -1:])
    return logits, tuple(caches)


def decode_step(cfg: ArchConfig, par: Parallel, params: Tree,
                token: jax.Array, pos: jax.Array, caches: Tree, max_seq: int):
    """One decode step. token (B,) int32; pos (B,) absolute positions."""
    x = embed_tokens(cfg, params, token[:, None])
    new_caches = []
    for stage, sp, c in zip(cfg.stages, params["stages"], caches):
        x, nc = T.stage_step(cfg, par, stage, sp, x, pos, c, max_seq)
        new_caches.append(nc)
    logits = logits_fn(cfg, params, x)
    return logits[:, 0], tuple(new_caches)


def init_caches(cfg: ArchConfig, par: Parallel, batch: int, max_seq: int,
                enc_len: int = 0) -> Tree:
    """Abstract decode-cache declaration (P tree) for all stages."""
    return tuple(T.init_stage_cache(cfg, par, s, batch, max_seq, enc_len)
                 for s in cfg.stages)


# ---------------------------------------------------------------------------
# Paged serving path (block-table addressed KV pages)
# ---------------------------------------------------------------------------
def init_paged_caches(cfg: ArchConfig, par: Parallel, n_slots: int,
                      num_pages: int, page_size: int,
                      dtype=None) -> Tree:
    """Abstract paged-cache declaration: attention KV lives in a shared
    (num_pages, page_size) pool per layer stack; recurrent state stays
    per-slot.  ``dtype`` overrides the bf16 pool default (f32 pools give
    bit-exact shared-vs-unshared prefix tests a clean footing).
    Encoder–decoder archs keep static cross K/V per request and are not
    paged (serve them on the contiguous path)."""
    if cfg.enc_dec:
        raise NotImplementedError("paged serving does not support enc-dec")
    return tuple(T.init_stage_cache_paged(cfg, par, s, n_slots, num_pages,
                                          page_size, dtype=dtype)
                 for s in cfg.stages)


def copy_pages(cfg: ArchConfig, caches: Tree, src, dst) -> Tree:
    """Apply queued copy-on-write page copies: ``pool[dst] = pool[src]``
    for every attention layer stack (recurrent per-slot state owns no
    pages).  src/dst are (n,) int32 page-id vectors from
    ``BlockTables.drain_copies``."""
    return tuple(T.stage_copy_pages(cfg, stage, cs, src, dst)
                 for stage, cs in zip(cfg.stages, caches))


def decode_step_paged(cfg: ArchConfig, par: Parallel, params: Tree,
                      token: jax.Array, pos: jax.Array, caches: Tree,
                      block_tables: jax.Array, context_lens=None,
                      max_seq: int = 0, use_kernel: bool = True):
    """One paged decode step.  token/pos (B,) int32; block_tables
    (B, nblk) int32 page ids (-1 = unassigned); context_lens (B,) int32
    live tokens per slot (0 = inactive).  The KV page reads/writes
    happen inside this (jitted) program — through the Pallas
    flash-decode kernel on feasible shapes (``use_kernel=True``, the
    default) or the XLA gather reference otherwise."""
    x = embed_tokens(cfg, params, token[:, None])
    new_caches = []
    for stage, sp, c in zip(cfg.stages, params["stages"], caches):
        x, nc = T.stage_step_paged(cfg, par, stage, sp, x, pos, c,
                                   block_tables, context_lens, max_seq,
                                   use_kernel)
        new_caches.append(nc)
    logits = logits_fn(cfg, params, x)
    return logits[:, 0], tuple(new_caches)


def prefill_step_paged(cfg: ArchConfig, par: Parallel, params: Tree,
                       tokens: jax.Array, caches: Tree,
                       bt_read: jax.Array, bt_write: jax.Array,
                       start, length, max_seq: int = 0,
                       use_kernel: bool = True):
    """Advance ONE request's paged prefill by one chunk of C tokens.

    tokens (1, C) int32 — the chunk's prompt slice, zero-padded past
    ``length``; bt_read/bt_write (nblk,) the request's block-table row
    and its writable (shared-masked) twin; start int32 page-aligned
    chunk origin; length int32 live tokens (1..C).  The chunk's K/V are
    scattered into the request's pool pages and its queries attend all
    previously-written context plus the in-chunk causal prefix — fused
    per layer, so no dense (B, bucket, hkv, dh) prefill cache ever
    exists.  Returns ``(last_logits, new_caches)`` where last_logits
    (1, V) are the logits at chunk row ``length - 1`` (only meaningful
    on the prompt's final chunk, where the engine samples the first
    token from them).

    Attention-stage architectures only (recurrent stages carry
    sequential state across chunks — they keep the whole-prompt path).
    """
    if cfg.enc_dec:
        raise NotImplementedError("chunked prefill does not support enc-dec")
    c = tokens.shape[1]
    positions = (jnp.asarray(start, jnp.int32)
                 + jnp.arange(c, dtype=jnp.int32))[None]
    x = embed_tokens(cfg, params, tokens)
    x = hint_act(x, par)
    new_caches = []
    for stage, sp, cch in zip(cfg.stages, params["stages"], caches):
        x, nc = T.stage_prefill_step_paged(cfg, par, stage, sp, x,
                                           positions, cch, bt_read,
                                           bt_write, start, length,
                                           max_seq, use_kernel)
        new_caches.append(nc)
    xl = jax.lax.dynamic_slice_in_dim(
        x, jnp.asarray(length, jnp.int32) - 1, 1, axis=1)
    logits = logits_fn(cfg, params, xl)
    return logits[:, 0], tuple(new_caches)


def splice_prefill(cfg: ArchConfig, caches: Tree, cache1: Tree, slot):
    """Contiguous splice: copy a batch-1 prefill cache into decode slot."""
    return jax.tree.map(lambda c, c1: c.at[:, slot].set(c1[:, 0]),
                        caches, cache1)


def splice_prefill_paged(cfg: ArchConfig, caches: Tree, cache1: Tree,
                         slot, bt_row: jax.Array) -> Tree:
    """Paged splice: scatter a batch-1 prefill cache into pool pages
    (attention) / decode slot (recurrent state)."""
    return tuple(T.stage_splice_paged(cfg, stage, cs, c1, slot, bt_row)
                 for stage, cs, c1 in zip(cfg.stages, caches, cache1))
