"""Core transformer layers: norms, RoPE, GQA attention (full / sliding /
local / cross), gated MLP, and token-choice MoE with sort-based dispatch.

Conventions
-----------
* All linear weights are (in_features, out_features); every matmul routes
  through :func:`repro.models.linear.dense` so quantized weight pytrees
  (``repro.core.qlinear.QLinear``) drop in transparently.
* ``init_*`` functions return trees of :class:`repro.models.param.P`
  (shape + logical sharding axes); ``apply_*`` take the materialized (or
  quantized) tree.
* Attention decode caches are ring buffers of ``window`` slots holding a
  parallel int32 absolute-position array for mask construction, so full
  and sliding-window attention share one code path.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Parallel, hint, in_mesh
from repro.models.linear import dense, expert_dense
from repro.models.param import P

Tree = Any
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ArchConfig, d: Optional[int] = None) -> Tree:
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": P((d,), (None,), "ones")}
    return {"scale": P((d,), (None,), "ones"), "bias": P((d,), (None,), "zeros")}


def apply_norm(cfg: ArchConfig, p: Tree, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
        return y.astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh), positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def init_attention(cfg: ArchConfig, par: Parallel, cross: bool = False) -> Tree:
    """Parameters stay at the architecture's TRUE n_kv_heads (faithful
    param counts); Megatron-style KV replication to the TP degree happens
    at runtime in _project_qkv (a broadcast, not extra parameters)."""
    d, dh = cfg.d_model, cfg.head_dim_
    hq = cfg.n_heads
    hkv = cfg.n_kv_heads
    p = {
        "wq": P((d, hq * dh), ("embed", "heads"), "scaled"),
        "wk": P((d, hkv * dh), ("embed", "kv_heads"), "scaled"),
        "wv": P((d, hkv * dh), ("embed", "kv_heads"), "scaled"),
        "wo": P((hq * dh, d), ("heads", "embed"), "scaled"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = P((hq * dh,), ("heads",), "zeros")
        p["bk"] = P((hkv * dh,), ("kv_heads",), "zeros")
        p["bv"] = P((hkv * dh,), ("kv_heads",), "zeros")
    if cfg.qk_norm and not cross:
        p["q_norm"] = P((dh,), (None,), "ones")
        p["k_norm"] = P((dh,), (None,), "ones")
    return p


def _qk_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _project_qkv(cfg: ArchConfig, par: Parallel, p: Tree, xq: jax.Array,
                 xkv: jax.Array, q_pos, kv_pos, use_rope: bool):
    dh = cfg.head_dim_
    hq = cfg.n_heads
    hkv = cfg.n_kv_heads
    hkv_run = par.kv_heads_run(hkv, hq)
    if "wqkv" in p and xq is xkv:
        # decode fast path: one fused matmul (and, when quantized, one
        # salient-channel gather) for all three projections
        g = p["wqkv"]
        q, k, v = g.split_out(dense(xq, g))
        if "bq" in p:
            q = q + p["bq"].astype(q.dtype)
            k = k + p["bk"].astype(k.dtype)
            v = v + p["bv"].astype(v.dtype)
    else:
        q = dense(xq, p["wq"], p.get("bq"))
        k = dense(xkv, p["wk"], p.get("bk"))
        v = dense(xkv, p["wv"], p.get("bv"))
    q = q.reshape(q.shape[:-1] + (hq, dh))
    k = k.reshape(k.shape[:-1] + (hkv, dh))
    v = v.reshape(v.shape[:-1] + (hkv, dh))
    if "q_norm" in p:
        q = _qk_norm(q, p["q_norm"])
        k = _qk_norm(k, p["k_norm"])
    if use_rope:
        q = rope(q, q_pos, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)
    if hkv_run > hkv:
        # Megatron KV replication: repeat each true KV head f× so the KV
        # tensors/cache shard over the TP axis.  Consecutive repeats keep
        # the q-group ↔ kv-head mapping of _attend intact (group g's f
        # replicas serve q heads [g·rep0, (g+1)·rep0)).
        f = hkv_run // hkv
        k = jnp.repeat(k, f, axis=-2)
        v = jnp.repeat(v, f, axis=-2)
    return q, k, v


def _attend(q, k, v, mask, softcap: Optional[float]):
    """q:(B,Sq,Hq,dh) k,v:(B,Sk,Hkv,dh) mask:(B,Sq,Sk) or (1,Sq,Sk) bool.

    K/V stay in their storage dtype (bf16) with f32 MXU accumulation —
    converting a 32k-token cache to f32 before the QK/AV contractions
    doubles decode HBM traffic for no precision benefit (§Perf: scores
    and softmax are f32 regardless; P is fed back at bf16, the standard
    flash-attention practice)."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    qr = q.reshape(b, sq, hkv, rep, dh)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qr, k,
                   preferred_element_type=jnp.float32)
    s = s.astype(jnp.float32) / math.sqrt(dh)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, hq, dh)


def _attend_chunked(q, k, v, q_pos, kv_pos, causal: bool,
                    window: Optional[int], softcap: Optional[float],
                    chunk: int):
    """Flash-style streaming softmax over KV chunks — O(Sq*chunk) memory.

    Positions are (B,Sq)/(B,Sk) int32; masking is positional so sliding
    windows and padding share the path.
    """
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    rep = hq // hkv
    n_chunks = sk // chunk
    assert sk % chunk == 0, (sk, chunk)
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, rep, dh) / math.sqrt(dh)
    kc = k.reshape(b, n_chunks, chunk, hkv, dh)
    vc = v.reshape(b, n_chunks, chunk, hkv, dh)
    pc = kv_pos.reshape(b, n_chunks, chunk)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs  # (B,chunk,Hkv,dh), (B,chunk)
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kb.astype(jnp.float32))
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        valid = pb[:, None, :] <= q_pos[:, :, None] if causal else pb[:, None, :] >= 0
        valid = jnp.logical_and(valid, pb[:, None, :] >= 0)
        if window is not None:
            valid = jnp.logical_and(valid, q_pos[:, :, None] - pb[:, None, :] < window)
        s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhrqk,bkhd->bhrqd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, rep, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), pc.swapaxes(0, 1)))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)


def make_cache(cfg: ArchConfig, par: Parallel, batch: int, window: int,
               n_layers: int, dtype=jnp.bfloat16) -> Dict[str, P]:
    """KV ring-buffer declaration for one scanned stack of `n_layers`."""
    dh = cfg.head_dim_
    hkv = par.kv_heads_run(cfg.n_kv_heads, cfg.n_heads)
    return {
        "k": P((n_layers, batch, window, hkv, dh),
               ("layers", "batch", None, "kv_heads", None), "zeros", dtype),
        "v": P((n_layers, batch, window, hkv, dh),
               ("layers", "batch", None, "kv_heads", None), "zeros", dtype),
        "p": P((n_layers, batch, window), ("layers", "batch", None), "zeros",
               jnp.int32),
    }


def attention_full(cfg: ArchConfig, par: Parallel, p: Tree, x: jax.Array,
                   positions: jax.Array, *, causal: bool = True,
                   window: Optional[int] = None, use_rope: bool = True,
                   xkv: Optional[jax.Array] = None,
                   kv_positions: Optional[jax.Array] = None,
                   cache_window: Optional[int] = None):
    """Training / prefill attention over a whole sequence (optionally cross).

    When ``cache_window`` is given, also returns the decode ring cache built
    from the K/V already computed here (no re-projection).
    """
    xkv = x if xkv is None else xkv
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(cfg, par, p, x, xkv, positions, kv_positions, use_rope)
    sk = k.shape[1]
    if sk > par.attn_chunk and sk % par.attn_chunk == 0:
        o = _attend_chunked(q, k, v, positions, kv_positions, causal, window,
                            cfg.logit_softcap, par.attn_chunk)
    else:
        sq = q.shape[1]
        qp, kp = positions[:, :, None], kv_positions[:, None, :]
        mask = kp <= qp if causal else jnp.ones((1, sq, sk), bool)
        # position -1 marks padding (engine left-pad); never attended —
        # the chunked path below has always masked pb >= 0 the same way
        mask = jnp.logical_and(mask, kp >= 0)
        if window is not None:
            mask = jnp.logical_and(mask, qp - kp < window)
        o = _attend(q, k, v, mask, cfg.logit_softcap)
    o = o.astype(x.dtype).reshape(x.shape[:-1] + (-1,))
    out = dense(o, p["wo"])
    if cache_window is None:
        return out
    return out, ring_cache_from_kv(k, v, kv_positions, cache_window)


def ring_cache_from_kv(k: jax.Array, v: jax.Array, positions: jax.Array,
                       window: int):
    """Build the ring cache from prefill K/V: keep the last `window` slots."""
    s = k.shape[1]
    if s >= window:
        k_c, v_c, p_c = (k[:, -window:], v[:, -window:], positions[:, -window:])
    else:
        pad = window - s
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        p_c = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
    # ring-order the slots so slot = pos % window
    idx = p_c % window
    order = jnp.argsort(idx, axis=1)
    take = lambda a: jnp.take_along_axis(a, order[..., None, None], axis=1) \
        if a.ndim == 4 else jnp.take_along_axis(a, order, axis=1)
    return {"k": take(k_c), "v": take(v_c), "p": take(p_c)}


def attention_decode(cfg: ArchConfig, par: Parallel, p: Tree, x: jax.Array,
                     pos: jax.Array, cache: Tree, *, use_rope: bool = True,
                     window: Optional[int] = None,
                     layer: Optional[int] = None):
    """Single-token decode against a ring cache.

    x: (B,1,D); pos: (B,) absolute position of the new token;
    cache: {"k","v": (B,W,Hkv,dh), "p": (B,W)} — or, when ``layer`` is
    given (unrolled decode, §Perf), the STACKED (L,B,W,Hkv,dh) buffers:
    the new slot scatters directly into the stacked cache so the update
    writes B·Hkv·dh elements instead of round-tripping a whole (B,W,…)
    slice through the scan carry.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(cfg, par, p, x, x, pos[:, None], pos[:, None], use_rope)
    bi = jnp.arange(b)
    if layer is None:
        w = cache["k"].shape[1]
        slot = pos % w
        ck = cache["k"].at[bi, slot].set(k[:, 0])
        cv = cache["v"].at[bi, slot].set(v[:, 0])
        cp = cache["p"].at[bi, slot].set(pos)
        new_cache = {"k": ck, "v": cv, "p": cp}
    else:
        w = cache["k"].shape[2]
        slot = pos % w
        ck_full = cache["k"].at[layer, bi, slot].set(k[:, 0])
        cv_full = cache["v"].at[layer, bi, slot].set(v[:, 0])
        cp_full = cache["p"].at[layer, bi, slot].set(pos)
        ck, cv, cp = ck_full[layer], cv_full[layer], cp_full[layer]
        new_cache = {"k": ck_full, "v": cv_full, "p": cp_full}
    qp = pos[:, None, None]
    kp = cp[:, None, :]
    mask = jnp.logical_and(kp <= qp, kp >= 0)
    if window is not None:
        mask = jnp.logical_and(mask, qp - kp < window)
    o = _attend(q, ck, cv, mask, cfg.logit_softcap)
    o = o.astype(x.dtype).reshape(b, 1, -1)
    return dense(o, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# Paged attention (serving runtime)
# ---------------------------------------------------------------------------
def make_paged_cache(cfg: ArchConfig, par: Parallel, num_pages: int,
                     page_size: int, n_layers: int,
                     dtype=jnp.bfloat16) -> Dict[str, P]:
    """KV *page pool* declaration for one scanned stack of ``n_layers``.

    Unlike :func:`make_cache` there is no per-slot position array: the
    layout is position-aligned (token ``t`` of a request lives at page
    ``block_table[t // page_size]``, slot ``t % page_size``), so the
    decode mask derives key positions from block/slot indices.  Reused
    pages therefore need no clearing — stale slots are masked out by the
    new owner's context length.

    The pool's head dim is ``ops.padded_head_dim(dh)``: on a real TPU,
    archs whose ``dh`` is off the 128-lane tile get zero-padded pool
    tiles so the flash-decode kernel can serve them instead of falling
    back to the XLA dense gather.  Writers pad K/V to the pool width;
    readers slice back to the logical ``dh`` (exact — see the kernel
    wrapper's docstring).

    One extra physical page beyond ``num_pages`` is allocated as the
    **dump page**: the chunked-prefill kernel's fused scatter needs a
    real write target for masked writes (shared/unassigned blocks,
    ragged chunk tails) where the XLA scatter uses ``mode="drop"``.  No
    block table ever references it (the allocator hands out ids
    ``[0, num_pages)``), so its garbage is unreachable, and the XLA
    paths' out-of-range sentinel ``num_pages + 1`` still drops.
    """
    from repro.kernels import ops
    dh = ops.padded_head_dim(cfg.head_dim_)
    hkv = par.kv_heads_run(cfg.n_kv_heads, cfg.n_heads)
    shape = (n_layers, num_pages + 1, page_size, hkv, dh)
    axes = ("layers", None, None, "kv_heads", None)
    return {"k": P(shape, axes, "zeros", dtype),
            "v": P(shape, axes, "zeros", dtype)}


def paged_key_positions(block_tables: jax.Array, page_size: int) -> jax.Array:
    """(B, nblk) block tables -> (B, nblk*page_size) implied key positions.

    Slot ``j`` of block ``i`` holds position ``i*page_size + j``;
    unassigned blocks (table entry < 0) yield position -1 (masked)."""
    b, nblk = block_tables.shape
    base = jnp.arange(nblk, dtype=jnp.int32)[:, None] * page_size
    kp = (base + jnp.arange(page_size, dtype=jnp.int32)[None, :])  # (nblk,ps)
    kp = jnp.broadcast_to(kp[None], (b, nblk, page_size))
    kp = jnp.where(block_tables[:, :, None] >= 0, kp, -1)
    return kp.reshape(b, nblk * page_size)


def scatter_pages(pool: Dict[str, jax.Array], k: jax.Array, v: jax.Array,
                  positions: jax.Array, bt_row: jax.Array) -> Dict[str, jax.Array]:
    """Scatter prefill K/V into pool pages (all layers at once).

    pool: {"k","v": (L, P, ps, hkv, dh)}; k/v: (L, S, hkv, dh) with the
    per-token absolute ``positions`` (S,) int32 (−1 = padding, dropped);
    ``bt_row`` (nblk,) is the owning request's block table.  Invalid
    tokens are routed to the out-of-range page id ``P`` and dropped by
    the scatter — no host-side compaction needed.
    """
    num_pages, ps = pool["k"].shape[1], pool["k"].shape[2]
    if k.shape[-1] < pool["k"].shape[-1]:    # lane-padded pool: pad tail
        padw = ((0, 0),) * (k.ndim - 1) + \
            ((0, pool["k"].shape[-1] - k.shape[-1]),)
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    t = positions.astype(jnp.int32)
    tc = jnp.clip(t, 0)
    blk = jnp.clip(tc // ps, 0, bt_row.shape[0] - 1)
    # both invalid positions AND unassigned blocks (bt_row entry -1)
    # route out of range — a -1 page id would wrap to the last pool page
    # and corrupt another request's KV
    valid = jnp.logical_and(t >= 0, bt_row[blk] >= 0)
    page = jnp.where(valid, bt_row[blk], num_pages)      # OOR -> dropped
    slot = tc % ps
    return {"k": pool["k"].at[:, page, slot].set(k, mode="drop"),
            "v": pool["v"].at[:, page, slot].set(v, mode="drop")}


def attention_decode_paged(cfg: ArchConfig, par: Parallel, p: Tree,
                           x: jax.Array, pos: jax.Array, cache: Tree,
                           block_tables: jax.Array, *, layer: int,
                           lengths: Optional[jax.Array] = None,
                           use_rope: bool = True,
                           window: Optional[int] = None,
                           use_kernel: bool = True):
    """Single-token decode against the shared page pool.

    x: (B,1,D); pos: (B,) absolute positions; cache: {"k","v"} page pools
    of shape (L, P, ps, hkv, dh); block_tables: (B, nblk) int32 page ids,
    -1 = unassigned; lengths: (B,) int32 live context per request
    (pos+1 for active rows, 0 for inactive — the engine plumbs them from
    ``BlockTables.context_lens``).  The new K/V scatter-writes into the
    owner's page (requests with no page for ``pos`` — inactive slots —
    scatter to the out-of-range sentinel and are dropped).

    The read has two paths, mirroring ``ops.mixed_matmul``:

    * **Pallas flash-decode kernel** (default on feasible shapes, needs
      ``lengths``): walks each request's pages straight out of the pool
      with scalar-prefetched block tables — per-token KV traffic scales
      with the LIVE context, and no (B, nblk*ps, hkv, dh) gather buffer
      ever exists in HBM (``repro.kernels.paged_attention``).
    * **XLA gather reference/fallback**: gathers the request's pages
      into a dense context and masks by the implied positions — the
      oracle the kernel is tested against, and the path taken when the
      shape is infeasible or ``use_kernel=False``.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(cfg, par, p, x, x, pos[:, None], pos[:, None],
                           use_rope)
    num_pages, ps = cache["k"].shape[1], cache["k"].shape[2]
    nblk = block_tables.shape[1]
    dh = k.shape[-1]
    dh_pool = cache["k"].shape[-1]
    kw, vw = k[:, 0], v[:, 0]
    if dh_pool > dh:        # lane-padded pool (ops.padded_head_dim)
        padw = ((0, 0), (0, 0), (0, dh_pool - dh))
        kw, vw = jnp.pad(kw, padw), jnp.pad(vw, padw)
    # -- write the new token's K/V into its page ------------------------
    blk = jnp.clip(pos // ps, 0, nblk - 1)
    bi = jnp.arange(b)
    page = block_tables[bi, blk]                         # (B,)
    page = jnp.where(page >= 0, page, num_pages)         # OOR -> dropped
    slot = pos % ps
    ck = cache["k"].at[layer, page, slot].set(kw, mode="drop")
    cv = cache["v"].at[layer, page, slot].set(vw, mode="drop")
    new_cache = {"k": ck, "v": cv}
    # -- attend over this request's pages -------------------------------
    from repro.kernels import ops
    hkv = k.shape[2]
    hq = q.shape[2]
    choice = (ops.paged_attention_blocks(ps, hkv, hq // hkv, dh,
                                         pool_dh=dh_pool)
              if use_kernel and lengths is not None else None)
    if choice is not None:
        o = ops.paged_attention(q[:, 0], ck[layer], cv[layer],
                                block_tables, lengths, window=window,
                                softcap=cfg.logit_softcap, bh=choice.bh)
        o = o[:, None]                                   # (B, 1, hq, dh)
    else:
        bt = jnp.clip(block_tables, 0)                   # (B, nblk)
        k_ctx = ck[layer][bt].reshape(b, nblk * ps, -1,
                                      dh_pool)[..., :dh]
        v_ctx = cv[layer][bt].reshape(b, nblk * ps, -1,
                                      dh_pool)[..., :dh]
        kp = paged_key_positions(block_tables, ps)       # (B, nblk*ps)
        qp = pos[:, None, None]
        mask = jnp.logical_and(kp[:, None, :] <= qp, kp[:, None, :] >= 0)
        if window is not None:
            mask = jnp.logical_and(mask, qp - kp[:, None, :] < window)
        o = _attend(q, k_ctx, v_ctx, mask, cfg.logit_softcap)
    o = o.astype(x.dtype).reshape(b, 1, -1)
    return dense(o, p["wo"]), new_cache


def attention_prefill_paged(cfg: ArchConfig, par: Parallel, p: Tree,
                            x: jax.Array, positions: jax.Array,
                            cache: Tree, bt_read: jax.Array,
                            bt_write: jax.Array, start, length, *,
                            layer: int, window: Optional[int] = None,
                            use_kernel: bool = True):
    """One CHUNK of paged prefill for one request: project the chunk's
    Q/K/V, write K/V straight into the request's pool pages and attend
    the chunk queries against all previously-written context pages plus
    the in-chunk causal prefix — fused in one kernel call, no dense
    per-request prefill cache.

    x: (1, C, D) the chunk's hidden states (rows past ``length`` are
    padding); positions: (1, C) absolute positions ``start + i``;
    cache: {"k","v"} page pools (L, P+1, ps, hkv, dh) — the last
    physical page is the masked-write dump page; bt_read: (nblk,) the
    request's block table; bt_write: (nblk,) its writable row (shared
    blocks -1, so prefix-attached pages are never rewritten); start:
    page-aligned chunk origin; length: live tokens in the chunk.

    K/V are cast to the pool dtype BEFORE both the write and the
    in-chunk attention, so the chunk attends exactly the bytes later
    chunks and decode steps will read back — which is what makes
    chunked and whole-prompt prefill agree in f32 pools.

    Dispatches the Pallas fused scatter+attend kernel on feasible
    shapes (mirroring ``attention_decode_paged``) and falls back to
    ``ops.paged_prefill_xla``, the bit-compatible dense-gather
    reference.
    """
    c = x.shape[1]
    q, k, v = _project_qkv(cfg, par, p, x, x, positions, positions, True)
    kw = k[0].astype(cache["k"].dtype)
    vw = v[0].astype(cache["v"].dtype)
    from repro.kernels import ops
    hkv = k.shape[2]
    hq = q.shape[2]
    dh = k.shape[-1]
    dh_pool = cache["k"].shape[-1]
    ps = cache["k"].shape[2]
    choice = (ops.paged_prefill_blocks(c, ps, hkv, hq // hkv, dh,
                                       pool_dh=dh_pool)
              if use_kernel else None)
    if choice is not None:
        o, kp, vp = ops.paged_prefill(
            q[0], kw, vw, cache["k"], cache["v"], bt_read, bt_write,
            start, length, layer=layer, window=window,
            softcap=cfg.logit_softcap, bh=choice.bh)
    else:
        o, kp, vp = ops.paged_prefill_xla(
            q[0], kw, vw, cache["k"], cache["v"], bt_read, bt_write,
            start, length, layer=layer, window=window,
            softcap=cfg.logit_softcap)
    o = o.astype(x.dtype).reshape(1, c, -1)
    return dense(o, p["wo"]), {"k": kp, "v": vp}


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------
def init_mlp(cfg: ArchConfig, d_ff: Optional[int] = None) -> Tree:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wg": P((d, f), ("embed", "ffn"), "scaled"),
        "wu": P((d, f), ("embed", "ffn"), "scaled"),
        "wd": P((f, d), ("ffn", "embed"), "scaled"),
    }


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


def apply_mlp(cfg: ArchConfig, p: Tree, x: jax.Array) -> jax.Array:
    if "wgu" in p:
        # decode fast path: fused gate+up (one matmul / one gather)
        gu = p["wgu"]
        g, u = gu.split_out(dense(x, gu))
        g = _act(cfg.act, g)
    else:
        g = _act(cfg.act, dense(x, p["wg"]))
        u = dense(x, p["wu"])
    return dense(g * u, p["wd"])


# ---------------------------------------------------------------------------
# Mixture of Experts — token-choice top-k, sort-free capacity dispatch.
# ---------------------------------------------------------------------------
def init_moe(cfg: ArchConfig) -> Tree:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    # router stays fp16/fp32 and replicated — tiny and saliency-critical
    # (same exemption class as norms; see DESIGN.md §4).
    return {
        "router": P((d, e), ("embed", None), "scaled", jnp.float32),
        "wg": P((e, d, f), ("experts", "embed", "ffn"), "scaled"),
        "wu": P((e, d, f), ("experts", "embed", "ffn"), "scaled"),
        "wd": P((e, f, d), ("experts", "ffn", "embed"), "scaled"),
    }


def moe_capacity(cfg: ArchConfig, n_tokens: int) -> int:
    m = cfg.moe
    cap = int(math.ceil(m.top_k * m.capacity_factor * n_tokens / m.n_experts))
    return max(8, ((cap + 7) // 8) * 8)


def apply_moe(cfg: ArchConfig, p: Tree, x: jax.Array,
              par: Optional[Parallel] = None) -> jax.Array:
    """Capacity-bound token-choice MoE.

    Dispatch is scatter-based (cumsum position-in-expert + one scatter),
    not the GShard O(T·E·C·D) one-hot einsum — the einsum dispatch FLOPs
    would exceed the expert FLOPs ~20× at Mixtral scale (see DESIGN.md).
    Overflowing tokens past capacity are dropped (standard token-choice
    semantics); their residual path passes through unchanged.

    Under a multi-device mesh the dispatch runs GROUP-LOCAL inside
    shard_map (GShard local-group capacity): plain-GSPMD scatter dispatch
    all-gathers every token to every device (measured 51GB/layer on
    mixtral prefill_32k — §Perf); with shard_map each device routes only
    its own tokens and the only cross-device traffic is the wd partial-sum
    (train) or the g·u feature gather (quantized serving).
    """
    m = cfg.moe
    b, s, d = x.shape
    if par is not None and _moe_shardable(par, b, s):
        return _apply_moe_shard_map(cfg, p, x, par)
    t = b * s
    xt = x.reshape(t, d)
    cap = moe_capacity(cfg, t)

    logits = xt.astype(jnp.float32) @ p["router"]          # (T,E)
    gate_w, gate_e = jax.lax.top_k(logits, m.top_k)        # (T,k)
    gate_w = jax.nn.softmax(gate_w, axis=-1).astype(x.dtype)

    flat_e = gate_e.reshape(-1)                            # (T*k,)
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1              # (T*k,E)
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    dest_e = jnp.where(keep, flat_e, m.n_experts)          # overflow -> ghost
    dest_c = jnp.where(keep, pos, 0)

    src = jnp.repeat(jnp.arange(t), m.top_k)
    buf = jnp.zeros((m.n_experts + 1, cap, d), x.dtype)
    buf = buf.at[dest_e, dest_c].set(xt[src])
    buf = buf[: m.n_experts]

    if "wgu" in p:
        # fused expert gate+up: one batched matmul (and one per-expert
        # salient-channel gather when quantized) for both projections
        g, u = p["wgu"].split_out(expert_dense(buf, p["wgu"]))
        g = _act(cfg.act, g)
    else:
        g = _act(cfg.act, expert_dense(buf, p["wg"]))
        u = expert_dense(buf, p["wu"])
    y = expert_dense(g * u, p["wd"])                       # (E,cap,D)

    gathered = y[dest_e.clip(0, m.n_experts - 1), dest_c]  # (T*k,D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_w.reshape(-1)[:, None].astype(gathered.dtype)
    out = jnp.zeros((t, d), gathered.dtype).at[src].add(gathered * w)
    return out.reshape(b, s, d)


def _moe_shardable(par: Parallel, b: int, s: int) -> bool:
    from repro.models.common import current_mesh
    mesh = current_mesh()
    if mesh is None or not hasattr(mesh, "devices"):
        return False
    if mesh.devices.size <= 1 or not par.shard_batch:
        return False
    return b % max(par.dp, 1) == 0 and s > 1


def _moe_dispatch_local(cfg: ArchConfig, router: jax.Array, xt: jax.Array):
    """Token-choice routing + capacity dispatch over LOCAL tokens.
    Returns (buf (E,cap,D), src, dest_e, dest_c, keep, gate_w)."""
    m = cfg.moe
    t, d = xt.shape
    cap = moe_capacity(cfg, t)
    logits = xt.astype(jnp.float32) @ router               # (T,E)
    gate_w, gate_e = jax.lax.top_k(logits, m.top_k)
    gate_w = jax.nn.softmax(gate_w, axis=-1).astype(xt.dtype)
    flat_e = gate_e.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    dest_e = jnp.where(keep, flat_e, m.n_experts)
    dest_c = jnp.where(keep, pos, 0)
    src = jnp.repeat(jnp.arange(t), m.top_k)
    buf = jnp.zeros((m.n_experts + 1, cap, d), xt.dtype)
    buf = buf.at[dest_e, dest_c].set(xt[src])
    return buf[: m.n_experts], src, dest_e, dest_c, keep, gate_w


def _moe_combine_local(cfg: ArchConfig, y: jax.Array, t: int, src, dest_e,
                       dest_c, keep, gate_w) -> jax.Array:
    m = cfg.moe
    gathered = y[dest_e.clip(0, m.n_experts - 1), dest_c]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_w.reshape(-1)[:, None].astype(gathered.dtype)
    return jnp.zeros((t, y.shape[-1]), gathered.dtype).at[src].add(
        gathered * w)


def _apply_moe_shard_map(cfg: ArchConfig, p: Tree, x: jax.Array,
                         par: Parallel) -> jax.Array:
    from jax.sharding import PartitionSpec as PS
    from repro.models.common import _batch_axes, current_mesh
    if "wgu" in p:
        # the shard-map path's specs are per-projection: serve it from
        # the group's unfused member views (same packed bytes, exact)
        wg, wu = p["wgu"].members()
        p = {**{k: v for k, v in p.items() if k != "wgu"},
             "wg": wg, "wu": wu}
    mesh = current_mesh()
    baxes = _batch_axes()
    quantized = hasattr(p["wg"], "__expert_matmul__")

    def leaf_spec_out_sharded(q, leaf_is=None):
        """Specs for wg/wu: output (N=d_ff) dim over 'model'."""
        if not quantized:
            return PS(None, None, "model")
        n = q.n
        return jax.tree.map(
            lambda a: PS(*([None] * (a.ndim - 1)), "model")
            if a.shape[-1] == n else PS(*([None] * a.ndim)), q)

    if quantized:
        wg_spec = leaf_spec_out_sharded(p["wg"])
        wu_spec = leaf_spec_out_sharded(p["wu"])
        # wd keeps its (permuted, packed) K intact: replicate it and
        # all-gather the g·u features inside (see module docstring)
        wd_spec = jax.tree.map(lambda a: PS(*([None] * a.ndim)), p["wd"])
    else:
        wg_spec = wu_spec = PS(None, None, "model")
        wd_spec = PS(None, "model", None)       # contracting dim sharded

    def local(router, wg, wu, wd, xs):
        # tokens are data-sharded and REPLICATED across the model axis
        # (deterministic dispatch → every model rank routes identically);
        # expert features are model-sharded.  The token-level partial is
        # psum'd once AFTER combine — combine is linear in y, and the
        # token layout is ~2.5× smaller than the capacity buffers.
        bl, sl, d = xs.shape
        xt = xs.reshape(bl * sl, d)
        buf, src, dest_e, dest_c, keep, gate_w = _moe_dispatch_local(
            cfg, router, xt)
        g = _act(cfg.act, expert_dense(buf, wg))
        u = expert_dense(buf, wu)
        gu = g * u                                   # (E,cap,F_loc)
        if quantized:
            gu = jax.lax.all_gather(gu, "model", axis=2, tiled=True)
            y = expert_dense(gu, wd)                 # full K, exact
            out = _moe_combine_local(cfg, y, xt.shape[0], src, dest_e,
                                     dest_c, keep, gate_w)
        else:
            y = expert_dense(gu, wd)                 # partial over F_loc
            out = _moe_combine_local(cfg, y, xt.shape[0], src, dest_e,
                                     dest_c, keep, gate_w)
            out = jax.lax.psum(out, "model")
        return out.reshape(bl, sl, -1)

    from repro.models.common import shard_map_compat
    fn = shard_map_compat(
        local, mesh=mesh,
        in_specs=(PS(None, None), wg_spec, wu_spec, wd_spec,
                  PS(baxes, None, None)),
        out_specs=PS(baxes, None, None))
    return fn(p["router"], p["wg"], p["wu"], p["wd"], x)


def moe_aux_loss(cfg: ArchConfig, x: jax.Array, router: jax.Array) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    m = cfg.moe
    t = x.shape[0] * x.shape[1]
    logits = x.reshape(t, -1).astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, -1)
    _, top1 = jax.lax.top_k(logits, 1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1[:, 0], m.n_experts), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return m.n_experts * jnp.sum(frac_tokens * frac_probs)
