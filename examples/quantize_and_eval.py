"""The paper's full pipeline on a trained subject: restorative-LoRA
preprocessing → structured mask → block-wise scale learning → packed
1.61-bit model → PPL comparison against the FP teacher.

    PYTHONPATH=src:. python examples/quantize_and_eval.py [--quick]

(Reuses the benchmark substrate; the first run trains the subject for a
few hundred steps and caches it under results/bench/.)
"""
import argparse

from benchmarks.common import (get_trained_tiny, perplexity, quantize)
from repro.core.bits import model_bits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip preprocessing (PTQ1.61* variant)")
    args = ap.parse_args()

    cfg, params, corpus = get_trained_tiny()
    fp = perplexity(cfg, params, corpus)
    print(f"fp16 ppl: {fp:.2f} (bigram ceiling "
          f"{corpus.bigram_ceiling_ppl():.2f})")

    qp = quantize("ptq161", cfg, params, corpus,
                  preprocess=not args.quick)
    rep = model_bits(qp)
    q = perplexity(cfg, qp, corpus)
    tag = "PTQ1.61*" if args.quick else "PTQ1.61"
    print(f"{tag} ppl: {q:.2f} at "
          f"{rep['avg_bits_per_quantized_weight']:.3f} bits/weight "
          f"({rep['quantized_weights']:,} weights)")


if __name__ == "__main__":
    main()
