"""Serve a PTQ1.61-quantized model with continuous batching.

    PYTHONPATH=src python examples/serve_quantized.py [--kernel]

Quantizes the tiny LM data-free, then runs a batch of variable-length
requests through the slot-based engine (ragged positions, prefill
buckets, greedy sampling).  --kernel dispatches the fused Pallas
mixed_matmul in interpret mode.
"""
import argparse

from repro.launch.serve import parse_args, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    argv = ["--arch", "tiny-lm", "--quantize", "datafree",
            "--requests", str(args.requests), "--slots", "3",
            "--max-seq", "128", "--max-new", "12",
            "--multiple", "16", "--min-dim", "64"]
    if args.kernel:
        argv.append("--kernel")
    out = run(parse_args(argv))
    assert out["all_done"]


if __name__ == "__main__":
    main()
