"""Programmatic dry-run of one (arch × shape × mesh) cell.

    python examples/multipod_dryrun.py --arch mixtral-8x22b --cell decode_32k

(Sets the 512-fake-device XLA flag itself, so run it as a fresh process —
not from inside an existing jax session.)
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_cell   # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--cell", default="decode_32k")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    args = ap.parse_args()

    rec = run_cell(args.arch, args.cell, args.mesh, force=True)
    print(json.dumps({k: v for k, v in rec.items()
                      if k in ("status", "preset", "roofline",
                               "useful_flops_ratio", "compile_s")},
                     indent=2, default=str))


if __name__ == "__main__":
    main()
