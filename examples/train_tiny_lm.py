"""End-to-end training driver (deliverable b): train the tiny LM for a
few hundred steps with checkpointing, a mid-run injected failure, and
automatic restart — the full fault-tolerance path on CPU.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""
import argparse
import tempfile

from repro.launch.train import parse_args, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="tinylm_ckpt_")

    out = run(parse_args([
        "--arch", "tiny-lm", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--lr", "5e-3", "--warmup", "50",
        "--ckpt-dir", ckpt, "--save-every", "50",
        "--fail-at-step", str(args.steps * 2 // 3),   # injected failure
        "--compression", "int8",                      # EF-int8 DP gradients
        "--log-every", "25",
    ]))
    print(f"\nfirst loss {out['first_loss']:.3f} -> final "
          f"{out['final_loss']:.3f}  (restarts: {out['restarts']})")
    print(f"checkpoints in {ckpt}")
    assert out["final_loss"] < out["first_loss"]


if __name__ == "__main__":
    main()
