"""Quickstart: quantize a model to 1.61 bits in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

Builds the tiny in-repo LM, applies data-free PTQ1.61 (structured mask +
analytic binarization), prints the Appendix-A bit accounting and a
before/after forward check.
"""
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.bits import model_bits, paper_closed_form
from repro.core.pipeline import quantize_params_data_free
from repro.core.qlinear import QuantConfig
from repro.models import model as M
from repro.models.common import Parallel


def main():
    cfg = registry.get("tiny-lm")
    par = Parallel(remat=False)
    params = M.init_params(cfg, par, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  params={M.n_params(cfg):,}")

    qcfg = QuantConfig(ratio=0.2, multiple=16)
    qparams = quantize_params_data_free(params, qcfg, min_dim=64)

    rep = model_bits(qparams)
    print(f"quantized weights : {rep['quantized_weights']:,}")
    print(f"bits/weight       : {rep['avg_bits_per_quantized_weight']:.3f}"
          f"  (paper 4096² closed form: "
          f"{paper_closed_form().total_bits:.3f})")
    print(f"exempt fraction   : {rep['exempt_fraction']:.2%} "
          f"(embeddings/norms/biases)")

    batch = {"tokens": jnp.ones((2, 64), jnp.int32),
             "targets": jnp.ones((2, 64), jnp.int32)}
    print(f"fp   loss: {float(M.forward_loss(cfg, par, params, batch)):.4f}")
    print(f"ptq  loss: {float(M.forward_loss(cfg, par, qparams, batch)):.4f}")
    print("ok — see examples/quantize_and_eval.py for the calibrated "
          "pipeline with learned scales")


if __name__ == "__main__":
    main()
