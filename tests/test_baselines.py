"""Baseline quantizers (RTN/GPTQ/AWQ/PB-LLM/BiLLM): error ordering,
bit accounting, and driver integration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.baselines import awq, billm, gptq, pbllm, rtn
from repro.core.baselines.driver import (method_bits, parse_method,
                                         quantize_model_baseline)


@pytest.fixture(scope="module")
def w(rng):
    return jnp.asarray(rng.normal(size=(256, 64)) * 0.02, jnp.float32)


def _err(a, b):
    return float(jnp.mean(jnp.square(a.astype(jnp.float32) -
                                     b.astype(jnp.float32))))


def test_rtn_monotone_in_bits(w):
    errs = [_err(w, rtn.rtn_quantize(w, b)) for b in (2, 3, 4, 8)]
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 1e-6


def test_gptq_beats_rtn_with_hessian(w, rng):
    """GPTQ's error compensation must beat plain RTN on the calibration
    objective ‖X(W−Ŵ)‖² (that is its derivation)."""
    x = np.asarray(rng.normal(size=(512, 256)), np.float32)
    x[:, : 32] *= 8.0     # activation outlier channels
    h = 2.0 * x.T @ x / x.shape[0]
    wq_g = gptq.gptq_quantize(w, h, bits=3)
    wq_r = rtn.rtn_quantize(w, 3)
    e_g = float(np.mean((x @ (np.asarray(w) - np.asarray(wq_g))) ** 2))
    e_r = float(np.mean((x @ (np.asarray(w) - np.asarray(wq_r))) ** 2))
    assert e_g < e_r, (e_g, e_r)


def test_awq_scales_reduce_weighted_error(w, rng):
    stat = np.abs(rng.normal(size=(256,)).astype(np.float32)) * 10 + 0.1
    x = rng.normal(size=(64, 256)).astype(np.float32) * stat[None, :]
    wq_a = awq.awq_quantize(w, stat, bits=2, x_sample=x)
    wq_r = rtn.rtn_quantize(w, 2)
    e_a = float(np.mean((x @ (np.asarray(w) - np.asarray(wq_a))) ** 2))
    e_r = float(np.mean((x @ (np.asarray(w) - np.asarray(wq_r))) ** 2))
    assert e_a <= e_r + 1e-9


def test_pbllm_preserves_salient(w):
    wq = pbllm.pbllm_quantize(w, salient_frac=0.1)
    wf = np.asarray(w)
    thresh = np.sort(np.abs(wf).ravel())[-int(0.1 * wf.size)]
    mask = np.abs(wf) >= thresh
    err_sal = np.abs(np.asarray(wq)[mask] - wf[mask]).mean()
    err_rest = np.abs(np.asarray(wq)[~mask] - wf[~mask]).mean()
    assert err_sal < err_rest


def test_billm_residual_binarization(w):
    wq = billm.billm_quantize(w, None)
    assert np.isfinite(np.asarray(wq)).all()
    # better than single-pass analytic binarization overall
    from repro.core.binarize import binarize_rtn
    e_b = _err(w, wq)
    e_1 = _err(w, binarize_rtn(w))
    assert e_b < e_1


def test_bit_accounting_ordering():
    """PTQ1.61 < BiLLM < PB-LLM effective bits (the paper's Table 1)."""
    assert method_bits("pbllm") == pytest.approx(2.7, abs=0.1)
    assert method_bits("billm") == pytest.approx(2.1, abs=0.01)
    from repro.core.bits import paper_closed_form
    ours = paper_closed_form().total_bits
    assert ours < method_bits("billm") < method_bits("pbllm")
    assert method_bits("gptq-2", 4096, 4096) < 2.1


def test_parse_method():
    assert parse_method("rtn-2") == ("rtn", 2)
    assert parse_method("gptq-4") == ("gptq", 4)
    assert parse_method("billm") == ("billm", None)
    with pytest.raises(ValueError):
        parse_method("foo-2")


def test_baseline_driver_end_to_end(rng):
    from repro.configs import registry
    from repro.models import model as M
    from repro.models.common import Parallel

    par = Parallel(remat=False, attn_chunk=64)
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, par, jax.random.PRNGKey(0))
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab))
    calib = [{"tokens": jnp.asarray(t)} for t, _ in
             corpus.batches(2, 32, 2, split="calib")]
    for method in ("rtn-4", "pbllm"):
        qp = quantize_model_baseline(cfg, par, params, calib, method,
                                     min_dim=32)
        loss = M.forward_loss(cfg, par, qp, {
            "tokens": jnp.ones((2, 32), jnp.int32),
            "targets": jnp.ones((2, 32), jnp.int32)})
        assert np.isfinite(float(loss)), method
