"""Equivalence tests for the §Perf optimized execution paths against
their plain-JAX oracles (the optimizations must not change the math)."""
import dataclasses
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.core import pipeline
from repro.core.qlinear import (QLinearGroup, QuantConfig, quantize_linear,
                                quantize_linear_group)
from repro.kernels import ops
from repro.launch.mesh import compat_make_mesh
from repro.models import layers as L
from repro.models import model as M
from repro.models import recurrent as R
from repro.models import transformer as T
from repro.models.common import Parallel
from repro.models.param import materialize


# ---------------------------------------------------------------------------
# sLSTM deferred-weight-gradient custom VJP == autodiff reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 3])
def test_slstm_custom_vjp_matches_autodiff(seed):
    cfg = registry.get("xlstm-1.3b").reduced()
    p = materialize(R.init_slstm(cfg), jax.random.PRNGKey(1))
    p_rec = {"r_gates": p["r_gates"].astype(jnp.float32),
             "b_gates": p["b_gates"]}
    rng = np.random.default_rng(seed)
    b, t, d = 2, 7, cfg.d_model
    zx = jnp.asarray(rng.normal(size=(b, t, 4 * d)) * 0.4, jnp.float32)
    z = jnp.zeros((b, d), jnp.float32)
    st = {"h": z, "c": z, "n": z + 1e-6, "m": z}

    def mk(fn):
        def loss(pr, zx):
            stN, hs = fn(cfg, pr, zx, st)
            return (jnp.sum(hs ** 2) + jnp.sum(stN["c"] ** 2) * 0.3
                    + jnp.sum(stN["h"]) * 0.1)
        return loss

    v1 = mk(R._slstm_scan)(p_rec, zx)
    v2 = mk(R._slstm_scan_ref)(p_rec, zx)
    np.testing.assert_allclose(float(v1), float(v2), rtol=1e-5)
    g1 = jax.grad(mk(R._slstm_scan), argnums=(0, 1))(p_rec, zx)
    g2 = jax.grad(mk(R._slstm_scan_ref), argnums=(0, 1))(p_rec, zx)
    for a, b2 in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        a = np.asarray(a, np.float32)
        b2 = np.asarray(b2, np.float32)
        den = np.abs(b2).max() + 1e-9
        assert np.abs(a - b2).max() / den < 1e-4, a.shape


# ---------------------------------------------------------------------------
# shard_map MoE == plain dispatch (fwd and grad), multi-device
# ---------------------------------------------------------------------------
def test_moe_shard_map_matches_fallback():
    if jax.device_count() < 4:
        pytest.skip("needs ≥4 devices (run under the dryrun env)")
    import dataclasses
    cfg = registry.get("mixtral-8x22b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = materialize(L.init_moe(cfg), jax.random.PRNGKey(0))
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.3,
                    jnp.float32)
    mesh = compat_make_mesh((2, 2), ("data", "model"))
    par = Parallel(tp=2, dp=2, remat=False, attn_chunk=32)

    def loss(p, use_par):
        return jnp.sum(L.apply_moe(cfg, p, x,
                                   par if use_par else None) ** 2)

    v1, g1 = jax.value_and_grad(lambda p: loss(p, False))(p)
    with mesh:
        v4, g4 = jax.jit(jax.value_and_grad(lambda p: loss(p, True)))(p)
    np.testing.assert_allclose(float(v1), float(v4), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# decode_unroll knob (kept despite being slower — must stay correct)
# ---------------------------------------------------------------------------
def test_decode_unroll_matches_scan():
    from repro.models import model as M
    cfg = registry.get("qwen3-4b").reduced()
    par_scan = Parallel(remat=False, attn_chunk=32, decode_unroll=False)
    par_unr = Parallel(remat=False, attn_chunk=32, decode_unroll=True)
    params = M.init_params(cfg, par_scan, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s, max_seq = 2, 12, 32
    toks = jnp.asarray(rng.integers(1, cfg.vocab - 1, (b, s + 1)),
                       jnp.int32)
    batch = {"tokens": toks[:, :s]}
    _, caches = M.prefill(cfg, par_scan, params, batch, max_seq)
    pos = jnp.full((b,), s, jnp.int32)
    l1, c1 = M.decode_step(cfg, par_scan, params, toks[:, s], pos, caches,
                           max_seq)
    l2, c2 = M.decode_step(cfg, par_unr, params, toks[:, s], pos, caches,
                           max_seq)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=5e-2, atol=5e-2)
    for a, b2 in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b2, np.float32),
                                   rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# Decode fast path: N-fused QKV / gate+up vs the unfused oracle
# ---------------------------------------------------------------------------
def test_fused_fp_model_matches_unfused():
    """fp fusion is pure concatenation — prefill and decode logits must
    match the per-projection model exactly (same contractions)."""
    cfg = registry.get("tiny-lm").reduced()
    par = Parallel(remat=False, attn_chunk=32)
    params = M.init_params(cfg, par, jax.random.PRNGKey(0))
    fused = T.fuse_params_for_decode(params)
    rng = np.random.default_rng(0)
    b, s, max_seq = 2, 12, 32
    toks = jnp.asarray(rng.integers(1, cfg.vocab - 1, (b, s + 1)), jnp.int32)
    l1, c1 = M.prefill(cfg, par, params, {"tokens": toks[:, :s]}, max_seq)
    l2, c2 = M.prefill(cfg, par, fused, {"tokens": toks[:, :s]}, max_seq)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-5, atol=1e-5)
    pos = jnp.full((b,), s, jnp.int32)
    d1, _ = M.decode_step(cfg, par, params, toks[:, s], pos, c1, max_seq)
    d2, _ = M.decode_step(cfg, par, fused, toks[:, s], pos, c2, max_seq)
    np.testing.assert_allclose(np.asarray(d1, np.float32),
                               np.asarray(d2, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_fused_group_qlinear_matches_member_oracle(rng):
    """A fused QLinearGroup (one quantization over concat(ws)) must give
    the SAME outputs as running its sliced per-member QLinears — the
    members are views over identical packed bytes."""
    k = 640
    ws = [jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
          for n in (128, 256, 128)]
    stat = jnp.asarray(rng.uniform(0.1, 10.0, k), jnp.float32)
    g = quantize_linear_group(ws, stat, QuantConfig(ratio=0.2, multiple=128))
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)
    ys = g.forward_split(x)
    for y, member in zip(ys, g.members()):
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(member.__matmul_x__(x),
                                              np.float32),
                                   rtol=1e-5, atol=1e-5)
    # kernel path over the fused layout vs the unfused XLA oracle
    gk = dataclasses.replace(
        g, inner=dataclasses.replace(g.inner, use_kernel=True))
    xb = x.astype(jnp.bfloat16)
    for y, member in zip(gk.forward_split(xb), g.members()):
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(member.__matmul_x__(xb),
                                              np.float32),
                                   rtol=2e-2, atol=0.06 * np.sqrt(k))


def test_fused_quantized_model_matches_unfused_oracle_scan():
    """Whole-model equivalence THROUGH the scanned stage stack: a
    data-free fused quantization vs the same packed data consumed
    unfused (fused groups sliced back into wq/wk/wv, wg/wu)."""
    cfg = registry.get("tiny-lm").reduced()
    par = Parallel(remat=False, attn_chunk=32)
    params = M.init_params(cfg, par, jax.random.PRNGKey(0))
    qcfg = QuantConfig(ratio=0.2, multiple=16)
    qp = pipeline.quantize_params_data_free(params, qcfg, fuse=True)
    # the transform must have produced stacked fused groups
    groups = [l for l in jax.tree.leaves(
        qp["stages"], is_leaf=lambda x: isinstance(x, QLinearGroup))
        if isinstance(l, QLinearGroup)]
    assert groups, "no QLinearGroup produced by fuse=True"
    oracle = T.unfuse_params_for_oracle(qp)
    rng = np.random.default_rng(1)
    b, s, max_seq = 2, 8, 32
    toks = jnp.asarray(rng.integers(1, cfg.vocab - 1, (b, s + 1)), jnp.int32)
    lq, cq = M.prefill(cfg, par, qp, {"tokens": toks[:, :s]}, max_seq)
    lu, cu = M.prefill(cfg, par, oracle, {"tokens": toks[:, :s]}, max_seq)
    np.testing.assert_allclose(np.asarray(lq, np.float32),
                               np.asarray(lu, np.float32),
                               rtol=1e-5, atol=1e-5)
    pos = jnp.full((b,), s, jnp.int32)
    dq, _ = M.decode_step(cfg, par, qp, toks[:, s], pos, cq, max_seq)
    du, _ = M.decode_step(cfg, par, oracle, toks[:, s], pos, cu, max_seq)
    np.testing.assert_allclose(np.asarray(dq, np.float32),
                               np.asarray(du, np.float32),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Pre-permuted vs stored-perm forwards (kernel path AND odd-shape fallback)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k_s,k_b,n,kernel_feasible", [
    (128, 512, 384, True),    # aligned: Pallas kernel path
    (128, 192, 256, True),    # bk must drop to the common divisor 64
    (128, 136, 192, False),   # N % 128 != 0: XLA fallback path
])
def test_pre_permuted_matches_stored_perm(rng, k_s, k_b, n, kernel_feasible):
    from repro.kernels import autotune
    k = k_s + k_b
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    stat = jnp.asarray(rng.uniform(0.1, 10.0, k), jnp.float32)
    q = quantize_linear(w, stat, QuantConfig(ratio=k_s / k, multiple=8,
                                             use_kernel=True))
    assert (q.k_s, q.k_b) == (k_s, k_b)
    assert (autotune.choose_blocks(4, q.k_s, q.k_b, n) is not None) \
        == kernel_feasible
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.bfloat16)
    xp = jnp.take(x, q.perm, axis=-1)
    y_stored = ops.mixed_matmul(x, q)
    y_pre = ops.mixed_matmul(xp, q, pre_permuted=True)
    np.testing.assert_array_equal(np.asarray(y_stored, np.float32),
                                  np.asarray(y_pre, np.float32))
    # both must agree with the XLA dequant oracle
    oracle = dataclasses.replace(q, use_kernel=False).__matmul_x__(x)
    np.testing.assert_allclose(np.asarray(y_stored, np.float32),
                               np.asarray(oracle, np.float32),
                               rtol=2e-2, atol=0.06 * np.sqrt(k) * 2)


# ---------------------------------------------------------------------------
# bf16 attention == f32 oracle within accumulation tolerance
# ---------------------------------------------------------------------------
def test_bf16_attention_close_to_f32_oracle(rng):
    b, sq, sk, hq, hkv, dh = 2, 4, 16, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(b, sq, hq, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, sk, hkv, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, sk, hkv, dh)), jnp.bfloat16)
    mask = jnp.tril(jnp.ones((1, sq, sk), bool), k=sk - sq)
    o = L._attend(q, k, v, mask, None)

    import math
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, hq // hkv, dh)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, k.astype(jnp.float32))
    s = s / math.sqrt(dh)
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    w = jax.nn.softmax(s, -1)
    o_ref = jnp.einsum("bhrqk,bkhd->bqhrd", w, v.astype(jnp.float32))
    o_ref = o_ref.reshape(b, sq, hq, dh)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# MoE expert gate+up fusion == per-projection oracle (expert_dense path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "mixtral-8x22b"])
def test_moe_expert_fusion_matches_unfused(arch):
    """Fusing the stacked expert wg/wu along N (one expert_dense batched
    matmul for both projections) must be exact for fp weights and
    bit-identical to the group's unfused member views when quantized."""
    cfg = registry.get(arch).reduced()
    par = Parallel(remat=False, attn_chunk=32)
    params = M.init_params(cfg, par, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "targets": jnp.ones((2, 16), jnp.int32)}
    base = M.forward_loss(cfg, par, params, batch)

    fused = T.fuse_params_for_decode(params)
    assert any("wgu" in bp.get("mlp", {}) and "router" in bp.get("mlp", {})
               for sp in fused["stages"] for bp in sp), \
        "MoE expert wg/wu must fuse into a QLinearGroup"
    lf = M.forward_loss(cfg, par, fused, batch)
    lu = M.forward_loss(cfg, par, T.unfuse_params_for_oracle(fused), batch)
    assert float(base) == float(lf) == float(lu), \
        "fp expert fusion is pure concatenation — must be exact"

    qp = pipeline.quantize_params_data_free(
        params, QuantConfig(ratio=0.25, multiple=16), min_dim=32,
        fuse=True)
    lq = M.forward_loss(cfg, par, qp, batch)
    lqu = M.forward_loss(cfg, par, T.unfuse_params_for_oracle(qp), batch)
    assert np.isfinite(float(lq))
    assert float(lq) == float(lqu), \
        "fused packed layout must match its unfused member views exactly"
