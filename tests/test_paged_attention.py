"""Paged flash-decode attention kernel: parity vs the XLA-gather
reference, autotuned KV tiles, the fully-inactive short-circuit, and
engine-level greedy identity (kernel on vs off) across preemption.

Parity structure mirrors test_kernels.py: the Pallas kernel (interpret
mode on CPU) against a pure-jnp oracle built exactly like
``layers.attention_decode_paged``'s fallback path — dense page gather,
implied-position mask, ``layers._attend``.  The engine-level identity
tests run in f32 (params AND KV pools): the two paths round differently
at the bf16 ulp, while an untrained tiny-lm's top-2 logit gaps sit at
that same ulp, so bf16 token identity would be a coin flip on ties —
in f32 the path delta (~1e-6 relative) is three orders below the gaps
and identity is robust.
"""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.kernels import autotune, ops
from repro.models import layers as L
from repro.models import model as M
from repro.models.common import Parallel
from repro.runtime.engine import Engine

PAR = Parallel(remat=False, attn_chunk=32)
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Kernel vs dense-gather oracle
# ---------------------------------------------------------------------------
def _oracle(q, k_pool, v_pool, bt, lens, window=None, softcap=None):
    """The XLA reference read: gather pages dense, mask implied
    positions, one-shot softmax (layers._attend semantics)."""
    b, hq, dh = q.shape
    _, ps, hkv, _ = k_pool.shape
    nblk = bt.shape[1]
    kctx = k_pool[jnp.clip(bt, 0)].reshape(b, nblk * ps, hkv, dh)
    vctx = v_pool[jnp.clip(bt, 0)].reshape(b, nblk * ps, hkv, dh)
    kp = L.paged_key_positions(jnp.asarray(bt), ps)
    pos = lens[:, None] - 1
    mask = jnp.logical_and(kp <= pos, kp >= 0)
    if window is not None:
        mask = jnp.logical_and(mask, pos - kp < window)
    o = L._attend(q[:, None], kctx, vctx, mask[:, None, :], softcap)
    return np.asarray(o[:, 0], np.float32)


def _pool_state(rng, num_pages, ps, hkv, dh, dtype):
    k_pool = jnp.asarray(rng.normal(size=(num_pages, ps, hkv, dh)), dtype)
    v_pool = jnp.asarray(rng.normal(size=(num_pages, ps, hkv, dh)), dtype)
    return k_pool, v_pool


@pytest.mark.parametrize("rep", [1, 2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_parity_ragged_gqa(rng, rep, dtype):
    """Ragged lengths (incl. a page-boundary length and an inactive
    len=0 row) across GQA head ratios."""
    b, num_pages, ps, hkv, dh, nblk = 4, 20, 8, 2, 16, 5
    hq = hkv * rep
    q = jnp.asarray(rng.normal(size=(b, hq, dh)), dtype)
    k_pool, v_pool = _pool_state(rng, num_pages, ps, hkv, dh, dtype)
    bt = np.full((b, nblk), -1, np.int32)
    bt[0, :3] = [3, 7, 1]
    bt[1, :1] = [0]
    bt[2, :5] = [2, 4, 5, 9, 11]
    lens = np.asarray([17, 8, 40, 0], np.int32)     # row 3: inactive
    out = np.asarray(ops.paged_attention(q, k_pool, v_pool,
                                         jnp.asarray(bt),
                                         jnp.asarray(lens)))
    ref = _oracle(q, k_pool, v_pool, jnp.asarray(bt), jnp.asarray(lens))
    tol = 1e-5 if dtype == jnp.float32 else 0.06 * math.sqrt(dh)
    np.testing.assert_allclose(out[:3], ref[:3], rtol=2e-2, atol=tol)
    # inactive row: exact zeros (never the reference's uniform garbage)
    np.testing.assert_array_equal(out[3], 0.0)


def test_kernel_parity_freed_pages_mid_table(rng):
    """-1 entries in the MIDDLE of a table (freed pages) are masked like
    the implied-position reference, not attended via a clamped fetch."""
    b, num_pages, ps, hkv, dh, nblk = 2, 16, 8, 2, 16, 4
    q = jnp.asarray(rng.normal(size=(b, hkv * 2, dh)), jnp.float32)
    k_pool, v_pool = _pool_state(rng, num_pages, ps, hkv, dh, jnp.float32)
    bt = np.asarray([[5, -1, 8, 2],
                     [1, 3, -1, -1]], np.int32)
    lens = np.asarray([29, 14], np.int32)
    out = np.asarray(ops.paged_attention(q, k_pool, v_pool,
                                         jnp.asarray(bt),
                                         jnp.asarray(lens)))
    ref = _oracle(q, k_pool, v_pool, jnp.asarray(bt), jnp.asarray(lens))
    np.testing.assert_allclose(out, ref, rtol=2e-2, atol=1e-5)


@pytest.mark.parametrize("window,softcap", [(12, None), (12, 30.0),
                                            (3, None), (None, 30.0)])
def test_kernel_parity_window_softcap(rng, window, softcap):
    b, num_pages, ps, hkv, dh, nblk = 3, 16, 8, 2, 16, 5
    q = jnp.asarray(rng.normal(size=(b, hkv * 2, dh)), jnp.float32)
    k_pool, v_pool = _pool_state(rng, num_pages, ps, hkv, dh, jnp.float32)
    bt = np.full((b, nblk), -1, np.int32)
    bt[0, :3] = [3, 7, 1]
    bt[1, :2] = [0, 6]
    bt[2, :5] = [2, 4, 5, 9, 11]
    lens = np.asarray([23, 9, 37], np.int32)
    out = np.asarray(ops.paged_attention(
        q, k_pool, v_pool, jnp.asarray(bt), jnp.asarray(lens),
        window=window, softcap=softcap))
    ref = _oracle(q, k_pool, v_pool, jnp.asarray(bt), jnp.asarray(lens),
                  window=window, softcap=softcap)
    np.testing.assert_allclose(out, ref, rtol=2e-2, atol=1e-5)


def test_kernel_bh_sweep_block_size_independent(rng):
    """Results must not depend on the kv-heads-per-block tile."""
    b, num_pages, ps, hkv, dh, nblk = 2, 12, 8, 4, 16, 3
    q = jnp.asarray(rng.normal(size=(b, hkv * 2, dh)), jnp.float32)
    k_pool, v_pool = _pool_state(rng, num_pages, ps, hkv, dh, jnp.float32)
    bt = np.asarray([[0, 1, 2], [3, 4, -1]], np.int32)
    lens = np.asarray([20, 11], np.int32)
    outs = [np.asarray(ops.paged_attention(
        q, k_pool, v_pool, jnp.asarray(bt), jnp.asarray(lens), bh=bh))
        for bh in (1, 2, 4)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


def test_fetched_page_counts_match_live_pages():
    """The index-map replay (shared kv_block_index — what serving_bench
    asserts on) issues exactly the live pages: ceil(len/ps) for active
    rows, the single clamped slack page for inactive ones, and only the
    in-window pages under a sliding window."""
    from repro.kernels.paged_attention import fetched_page_counts
    ps = 8
    bt = np.asarray([[3, 7, 1, -1],      # 17 live tokens -> 3 pages
                     [0, -1, -1, -1],    # 8 live -> 1 page
                     [2, 4, 5, 9],       # 32 live -> 4 pages
                     [-1, -1, -1, -1]],  # inactive -> 1 clamped page
                    np.int32)
    lens = np.asarray([17, 8, 32, 0], np.int32)
    np.testing.assert_array_equal(
        fetched_page_counts(bt, lens, ps), [3, 1, 4, 1])
    # sliding window 8 over 32 live tokens: pages below the window
    # start clamp onto the first in-window page -> 2 fetches at most
    # (window spans positions 24..31 = page 3, plus the clamp target)
    win = fetched_page_counts(bt, lens, ps, window=8)
    assert win[2] <= 2
    # every row obeys the serving_bench gate: pages*ps <= live + ps
    for fetched, live in zip(fetched_page_counts(bt, lens, ps), lens):
        assert fetched * ps <= live + ps


# ---------------------------------------------------------------------------
# Autotuned KV tiles
# ---------------------------------------------------------------------------
def test_choose_paged_blocks():
    c = autotune.choose_paged_blocks(8, 4, 128, 16)
    assert c is not None and 8 % c.bh == 0
    assert c.vmem_bytes <= autotune.VMEM_BUDGET
    assert c.kv_bytes_per_token == 2 * 8 * 128 * 2
    # plenty of VMEM at serving shapes: all kv heads in one block
    assert c.bh == 8
    # a starved budget still degrades to bh=1 before giving up
    tight = autotune.choose_paged_blocks(8, 4, 128, 16,
                                         vmem_budget=1 << 16)
    assert tight is None or tight.bh <= c.bh
    assert autotune.choose_paged_blocks(0, 4, 128, 16) is None


def test_paged_read_bytes_page_slack():
    """The cost-model contract serving_bench asserts: whole-page reads
    cost at most one page of slack past the live tokens."""
    per_tok = autotune.paged_kv_bytes_per_token(4, 64)
    for n in (1, 15, 16, 17, 100):
        got = autotune.paged_read_bytes(n, 16, 4, 64)
        assert got >= n * per_tok
        assert got <= (n + 16) * per_tok


# ---------------------------------------------------------------------------
# Layer-level dispatch and the fully-inactive short-circuit
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def subject():
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    return cfg, params


def _to_f32(tree):
    return jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        tree)


def _paged_state(cfg, n_slots=2, num_pages=16, ps=8, dtype=jnp.float32):
    caches = M.init_paged_caches(cfg, PAR, n_slots, num_pages, ps)
    from repro.models.param import materialize
    caches = materialize(caches, jax.random.PRNGKey(1))
    if dtype == jnp.float32:
        caches = _to_f32(caches)
    return caches


def test_decode_step_paged_kernel_matches_xla(subject, rng):
    """Whole-model one-step parity: kernel vs XLA-gather reference on
    identical pool state (f32 so the comparison is tight)."""
    cfg, params = subject
    params = _to_f32(params)
    caches = _paged_state(cfg)
    caches = jax.tree.map(
        lambda a: jnp.asarray(rng.normal(size=a.shape) * 0.3, a.dtype)
        if a.ndim >= 4 else a, caches)
    bt = np.asarray([[0, 1, -1, -1, -1, -1, -1, -1],
                     [2, 3, 4, -1, -1, -1, -1, -1]], np.int32)
    lens = np.asarray([10, 19], np.int32)
    tok = jnp.asarray(rng.integers(1, cfg.vocab, size=2), jnp.int32)
    pos = jnp.asarray(lens - 1)
    args = (params, tok, pos, caches, jnp.asarray(bt), jnp.asarray(lens))
    lk, ck = M.decode_step_paged(cfg, PAR, *args, max_seq=64,
                                 use_kernel=True)
    lx, cx = M.decode_step_paged(cfg, PAR, *args, max_seq=64,
                                 use_kernel=False)
    np.testing.assert_allclose(np.asarray(lk, np.float32),
                               np.asarray(lx, np.float32),
                               rtol=1e-4, atol=1e-4)
    # both paths scatter the same new K/V
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ck, cx)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_decode_step_paged_inactive_short_circuit(subject, rng, use_kernel):
    """Every block-table row -1 (no slot owns a page): the stage walk is
    skipped on device — caches come back untouched and the logits are
    finite (regression: the reference used to gather + mask a fully
    dense (B, nblk*ps) context for nothing)."""
    cfg, params = subject
    caches = _paged_state(cfg, dtype=jnp.bfloat16)
    bt = np.full((2, 8), -1, np.int32)
    lens = np.zeros((2,), np.int32)
    tok = jnp.asarray(rng.integers(1, cfg.vocab, size=2), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    logits, new_caches = M.decode_step_paged(
        cfg, PAR, params, tok, pos, caches, jnp.asarray(bt),
        jnp.asarray(lens), max_seq=64, use_kernel=use_kernel)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), caches, new_caches)


# ---------------------------------------------------------------------------
# Engine-level greedy identity (kernel on vs off)
# ---------------------------------------------------------------------------
def _f32_engine(cfg, params, **kw):
    eng = Engine(cfg, PAR, params, n_slots=2, max_seq=64,
                 prefill_buckets=(16, 32), paged=True, page_size=8, **kw)
    eng.backend.caches = _to_f32(eng.backend.caches)
    return eng


@pytest.mark.parametrize("tight_pool", [False, True])
def test_engine_greedy_kernel_vs_xla_identical(subject, tight_pool):
    """Acceptance: greedy tokens through the flash-decode kernel are
    IDENTICAL to the XLA-gather reference engine — including through
    pool exhaustion, preemption and full-context resume (tight pool).
    f32 end-to-end; see module docstring for why bf16 can't carry a
    token-identity claim on an untrained subject."""
    cfg, params = subject
    params = _to_f32(params)
    local = np.random.default_rng(0)
    if tight_pool:
        prompts = [local.integers(1, cfg.vocab, size=13).astype(np.int32)
                   for _ in range(3)]
        kw = dict(pool_pages=6)
        max_new = 20
    else:
        prompts = [local.integers(1, cfg.vocab, size=n).astype(np.int32)
                   for n in (4, 9, 13, 7, 21)]
        kw = {}
        max_new = 6

    def run(kernel):
        eng = _f32_engine(cfg, params, paged_kernel=kernel, **kw)
        reqs = [eng.submit(p, max_new=max_new) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs], sum(r.preemptions
                                                 for r in reqs)
    toks_k, pre_k = run(True)
    toks_x, pre_x = run(False)
    assert toks_k == toks_x
    if tight_pool:
        assert pre_k >= 1 and pre_k == pre_x


def test_engine_greedy_kernel_vs_xla_hybrid_window(rng):
    """The sliding-window kernel branch through the FULL dispatch stack
    (engine → stage_step_paged → attention_decode_paged kernel path):
    recurrentgemma's local-attention blocks carry window=_kind_window
    into the kernel, interleaved with per-slot recurrent state.  The
    workload pushes contexts to 44 tokens against local_window=32, so
    the window mask BINDS and the below-window page-skip clamp
    (first > 0) runs, not just the causal tail.  f32 end-to-end,
    kernel vs XLA reference — greedy tokens identical."""
    cfg = registry.get("recurrentgemma-2b").reduced()
    assert cfg.local_window == 32
    params = _to_f32(M.init_params(cfg, PAR, jax.random.PRNGKey(0)))
    local = np.random.default_rng(0)
    prompts = [local.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (30, 11, 37)]      # 37 truncates to the 32 bucket

    def run(kernel):
        eng = _f32_engine(cfg, params, paged_kernel=kernel)
        reqs = [eng.submit(p, max_new=12) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs]

    assert run(True) == run(False)


def test_engine_context_lens_follow_slots(subject, rng):
    """BlockTables.context_lens is the kernel's scalar-prefetch length
    operand: pos+1 while a slot decodes, 0 once released."""
    cfg, params = subject
    eng = Engine(cfg, PAR, params, n_slots=2, max_seq=64,
                 prefill_buckets=(16, 32), paged=True, page_size=8)
    r = eng.submit(rng.integers(1, cfg.vocab, size=9).astype(np.int32),
                   max_new=3)
    eng.step()
    # lens was fixed at pos+1 for the write this tick performed; pos has
    # since advanced past it, so a live slot reads lens == pos
    lens = eng.backend.tables.context_lens()
    assert lens[0] == eng.pos[0] > 0       # live slot
    assert lens[1] == 0                    # empty slot
    eng.run()
    assert r.done
    assert (eng.backend.tables.context_lens() == 0).all()


# ---------------------------------------------------------------------------
# Head-dim padding (lane-tile pools for dh off the 128 TPU tile)
# ---------------------------------------------------------------------------
def test_kernel_padded_pool_matches_unpadded(rng):
    """A lane-padded pool (zero tails past the logical dh) produces the
    same attention output as the unpadded layout: zero q lanes add
    nothing to q·k, the softmax scale stays 1/sqrt(dh_logical), and the
    padded output columns are sliced off."""
    b, num_pages, ps, hkv, dh, nblk = 3, 12, 8, 2, 16, 4
    q = jnp.asarray(rng.normal(size=(b, hkv * 2, dh)), jnp.float32)
    k_pool, v_pool = _pool_state(rng, num_pages, ps, hkv, dh, jnp.float32)
    bt = np.asarray([[3, 7, -1, -1],
                     [0, 1, 2, 5],
                     [-1, -1, -1, -1]], np.int32)
    lens = np.asarray([13, 30, 0], np.int32)
    out = np.asarray(ops.paged_attention(q, k_pool, v_pool,
                                         jnp.asarray(bt),
                                         jnp.asarray(lens)))
    pad = ((0, 0), (0, 0), (0, 0), (0, 16))        # dh 16 -> 32 pool tile
    out_p = np.asarray(ops.paged_attention(q, jnp.pad(k_pool, pad),
                                           jnp.pad(v_pool, pad),
                                           jnp.asarray(bt),
                                           jnp.asarray(lens)))
    assert out_p.shape == out.shape                # sliced back to dh
    np.testing.assert_allclose(out_p, out, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(out_p[2], 0.0)   # inactive row intact


def test_padded_head_dim_policy_and_gate(monkeypatch):
    """padded_head_dim rounds to the lane tile only off-tile and only on
    real TPU backends; the feasibility gate accepts a padded pool for a
    dh that would otherwise be rejected."""
    assert ops.padded_head_dim(96) == 96           # interpret: no tax
    monkeypatch.setattr(ops, "INTERPRET", False)
    assert ops.padded_head_dim(128) == 128
    assert ops.padded_head_dim(96) == 128
    assert ops.padded_head_dim(200) == 256
    # dh=96 alone fails the lane floor; with its padded pool it passes
    assert ops.paged_attention_blocks(8, 2, 2, 96, pool_dh=96) is None
    assert ops.paged_attention_blocks(8, 2, 2, 96, pool_dh=128) is not None
    # a pool narrower than the query head dim is never feasible
    monkeypatch.setattr(ops, "INTERPRET", True)
    assert ops.paged_attention_blocks(8, 2, 2, 96, pool_dh=64) is None


@pytest.mark.parametrize("use_kernel", [True, False])
def test_engine_greedy_identical_with_padded_pools(subject, monkeypatch,
                                                   use_kernel):
    """End-to-end padded layout: force padded_head_dim to widen the pool
    (as a real TPU would for tiny-lm's dh=32), serve a full workload
    through BOTH read paths, and require greedy tokens identical to the
    unpadded engine — writers pad, readers slice, nothing leaks."""
    cfg, params = subject
    params = _to_f32(params)
    local = np.random.default_rng(0)
    prompts = [local.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 9, 13, 7, 21)]

    def run(force_pad):
        if force_pad:
            monkeypatch.setattr(ops, "padded_head_dim",
                                lambda dh: dh * 2)
        else:
            monkeypatch.setattr(ops, "padded_head_dim", lambda dh: dh)
        eng = _f32_engine(cfg, params, paged_kernel=use_kernel)
        dh_pool = eng.backend.caches[0][0]["k"].shape[-1]
        assert dh_pool == cfg.head_dim_ * (2 if force_pad else 1)
        reqs = [eng.submit(p, max_new=6) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs]

    assert run(False) == run(True)
