"""Launcher-layer consistency: presets, input specs and abstract
quantized declarations build for every (arch × cell) — no device work
(P trees and ShapeDtypeStructs only), so the full 40-cell matrix is
checked in seconds.  The actual lower+compile evidence lives in
results/dryrun (launch/dryrun.py)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as PS

from repro.configs import registry
from repro.configs.base import SHAPE_CELLS, cell_applicable
from repro.core.qlinear import QLinear, QuantConfig
from repro.distributed.sharding import Rules
from repro.launch.inputs import (decode_inputs, prefill_inputs,
                                 train_inputs)
from repro.launch.qdeclare import declare_qlinear, declare_quantized
from repro.models import model as M
from repro.models.common import Parallel
from repro.models.param import P

RULES = Rules()
PAR = Parallel(tp=16, dp=16)


def _leaves_with_specs(abstract, specs):
    a = jax.tree.leaves(abstract,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, PS))
    return a, s


@pytest.mark.parametrize("arch", registry.ASSIGNED)
@pytest.mark.parametrize("cell", [c.name for c in SHAPE_CELLS])
def test_cell_specs_build_and_divide(arch, cell):
    """Every live cell's abstract inputs build, and every sharded dim is
    divisible by its mesh axes (the pjit boundary requirement that broke
    three archs before the ctx-sharded cache fix)."""
    from repro.configs.base import cell_by_name
    cfg = registry.get(arch)
    c = cell_by_name(cell)
    ok, why = cell_applicable(cfg, c)
    if not ok:
        assert "full-attention" in why
        return
    par = Parallel(tp=16, dp=16,
                   shard_batch=c.global_batch >= 16)
    axis_size = {"data": 16, "model": 16, "pod": 2}

    def check(abstract, specs):
        a, s = _leaves_with_specs(abstract, specs)
        assert len(a) == len(s)
        for sds, spec in zip(a, s):
            for dim, ax in zip(sds.shape, tuple(spec) + (None,) * 8):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else tuple(ax)
                total = int(np.prod([axis_size[x] for x in axes]))
                assert dim % total == 0, (arch, cell, sds.shape, spec)

    if c.kind == "train":
        inp, spec = train_inputs(cfg, c, par, RULES)
        check(inp, spec)
    elif c.kind == "prefill":
        inp, spec = prefill_inputs(cfg, c, par, RULES)
        check(inp, spec)
    else:
        (tok, pos, caches), (ts, ps2, cs) = decode_inputs(cfg, c, par,
                                                          RULES)
        check(caches, cs)


@pytest.mark.parametrize("arch", registry.ASSIGNED)
def test_declare_quantized_consistent(arch):
    """Abstract QLinear declarations mirror the real quantizer's shapes
    (packing divisibility, salient counts, spec-tree congruence)."""
    cfg = registry.get(arch)
    qcfg = QuantConfig(ratio=0.2, multiple=128)
    abstract, specs = declare_quantized(cfg, PAR, qcfg, RULES)
    n_q = 0

    def visit(a, s):
        nonlocal n_q
        if isinstance(a, QLinear):
            n_q += 1
            assert isinstance(s, QLinear)
            assert a.k_s % 128 == 0
            assert (a.k - a.k_s) % 8 == 0
            assert a.w4.shape[-2] == a.k_s // 2
            assert a.bits.shape[-2] == (a.k - a.k_s) // 8
    jax.tree.map(visit, abstract, specs,
                 is_leaf=lambda x: isinstance(x, QLinear))
    assert n_q > 0


def test_declare_qlinear_matches_quantize_linear(rng):
    """The abstract declaration predicts the real packed shapes."""
    from repro.core.qlinear import quantize_linear
    k, n = 1024, 256
    decl = declare_qlinear(P((k, n), ("embed", "ffn")),
                           QuantConfig(ratio=0.2, multiple=128))
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.02, jnp.float32)
    real = quantize_linear(w, None, QuantConfig(ratio=0.2, multiple=128))
    for f in QLinear._FIELDS:
        assert getattr(decl, f).shape == getattr(real, f).shape, f
        assert getattr(decl, f).dtype == getattr(real, f).dtype, f


def test_presets_cover_all_cells():
    """make_preset returns sane knobs for every cell without touching
    jax device state (uses a mesh-shaped stub)."""
    class StubDevices:
        size = 256

    class StubMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
        devices = StubDevices()
    from repro.launch.presets import make_preset
    from repro.configs.base import cell_by_name
    for arch in registry.ASSIGNED:
        cfg = registry.get(arch)
        for cell in SHAPE_CELLS:
            if not cell_applicable(cfg, cell)[0]:
                continue
            p = make_preset(cfg, cell, StubMesh())
            assert p.par.tp == 16
            assert p.par.microbatches >= 1
            assert p.par.remat == (cell.kind == "train")
