"""Serving engine, data pipeline, recurrent-cell equivalences."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.data.synthetic import (CorpusConfig, SyntheticCorpus,
                                  calibration_set)
from repro.models import model as M
from repro.models import recurrent as R
from repro.models.common import Parallel
from repro.runtime.engine import Engine

PAR = Parallel(remat=False, attn_chunk=32)


# ---------------------------------------------------------------------------
# Synthetic corpus
# ---------------------------------------------------------------------------
def test_corpus_determinism():
    c1 = SyntheticCorpus(CorpusConfig(seed=7))
    c2 = SyntheticCorpus(CorpusConfig(seed=7))
    np.testing.assert_array_equal(c1.document(5, 64), c2.document(5, 64))
    assert not np.array_equal(c1.document(5, 64), c1.document(6, 64))


def test_corpus_host_sharding_disjoint():
    c = SyntheticCorpus(CorpusConfig())
    got = []
    for host in range(2):
        for tok, _ in c.batches(2, 16, 2, host=host, n_hosts=2):
            got.append(tok)
    # host-0 and host-1 batches must differ (disjoint documents)
    assert not np.array_equal(got[0], got[2])


def test_corpus_has_learnable_structure():
    """Bigram process: the same prefix token constrains successors to the
    `branch` table — mutual information is present."""
    c = SyntheticCorpus(CorpusConfig(vocab=256, branch=4))
    doc = c.document(0, 2000)
    succ = {}
    for a, b in zip(doc[:-1], doc[1:]):
        succ.setdefault(int(a), set()).add(int(b))
    multi = [len(v) for t, v in succ.items() if len(v) > 0]
    assert np.mean(multi) <= 4 + 1e-9          # bounded out-degree


def test_calibration_set_shape():
    c = SyntheticCorpus(CorpusConfig())
    calib = calibration_set(c, n_segments=4, seq=128)
    assert len(calib) == 4
    assert calib[0][0].shape == (1, 128)


# ---------------------------------------------------------------------------
# Recurrent cell: sequence form == step form (the decode contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["rglru", "mlstm", "slstm"])
def test_recurrent_seq_equals_steps(kind, rng):
    cfg = registry.get({"rglru": "recurrentgemma-2b",
                        "mlstm": "xlstm-1.3b",
                        "slstm": "xlstm-1.3b"}[kind]).reduced()
    from repro.models.param import materialize
    init = {"rglru": R.init_rglru, "mlstm": R.init_mlstm,
            "slstm": R.init_slstm}[kind]
    p = materialize(init(cfg), jax.random.PRNGKey(1))
    b, s = 2, 8
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)) * 0.3, jnp.float32)

    if kind == "rglru":
        y_seq, hN, conv = R.rglru_seq(cfg, p, x)
        h = jnp.zeros((b, cfg.rnn_width or cfg.d_model), jnp.float32)
        conv_s = jnp.zeros((b, cfg.conv_width - 1,
                            cfg.rnn_width or cfg.d_model), x.dtype)
        outs = []
        for t in range(s):
            o, h, conv_s = R.rglru_step(cfg, p, x[:, t:t+1], h, conv_s)
            outs.append(o)
    elif kind == "mlstm":
        y_seq, st = R.mlstm_seq(cfg, p, x, chunk=4)
        state = None
        outs = []
        dk = cfg.d_model // cfg.n_heads
        dv = int(cfg.mlstm_proj_factor * cfg.d_model) // cfg.n_heads
        state = {"c": jnp.zeros((b, cfg.n_heads, dk, dv)),
                 "n": jnp.zeros((b, cfg.n_heads, dk))}
        for t in range(s):
            o, state = R.mlstm_step(cfg, p, x[:, t:t+1], state)
            outs.append(o)
    else:
        y_seq, st = R.slstm_seq(cfg, p, x)
        d = cfg.d_model
        state = {k: jnp.zeros((b, d)) for k in ("h", "c", "m")}
        state["n"] = jnp.zeros((b, d)) + 1e-6
        outs = []
        for t in range(s):
            o, state = R.slstm_step(cfg, p, x[:, t:t+1], state)
            outs.append(o)

    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq, np.float32),
                               np.asarray(y_step, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine():
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    return cfg, Engine(cfg, PAR, params, n_slots=2, max_seq=64,
                       prefill_buckets=(16, 32))


def test_engine_completes_requests(engine, rng):
    cfg, eng = engine
    reqs = [eng.submit(rng.integers(1, cfg.vocab, size=n).astype(np.int32),
                       max_new=5) for n in (4, 9, 13)]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)


def test_engine_greedy_matches_decode_reference(rng):
    """Engine decode (continuous batching, slot splicing, ring cache)
    must reproduce a manual prefill + decode_step loop.  (Comparing
    against re-prefilling the growing sequence is covered — with
    tolerance — by test_prefill_decode_consistency; exact token equality
    on an untrained model is only meaningful against the same incremental
    cache path, since near-tied bf16 logits flip argmax.)"""
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    prompt = rng.integers(1, cfg.vocab, size=7).astype(np.int32)
    max_seq = 32

    eng = Engine(cfg, PAR, params, n_slots=1, max_seq=max_seq,
                 prefill_buckets=(8, 16))
    r = eng.submit(prompt, max_new=4)
    eng.run()

    # reference: the same left-padded bucket prefill + decode_step loop
    # (pad positions are -1 — masked out of attention, engine convention)
    b = 8  # bucket for a 7-token prompt
    toks = np.zeros((1, b), np.int32)
    toks[0, -len(prompt):] = prompt
    idx = np.arange(b, dtype=np.int32)
    positions = np.where(idx >= b - len(prompt),
                         idx - (b - len(prompt)), -1)[None]
    logits, caches = M.prefill(cfg, PAR, params,
                               {"tokens": jnp.asarray(toks),
                                "positions": jnp.asarray(positions)},
                               max_seq)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(out) < 4:
        lg, caches = M.decode_step(cfg, PAR, params,
                                   jnp.asarray([out[-1]], jnp.int32),
                                   jnp.asarray([pos], jnp.int32),
                                   caches, max_seq)
        out.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert r.out_tokens == out
