"""Fault tolerance: atomic checkpoints, restart determinism, failure
injection via the Supervisor, straggler watchdog, elastic re-mesh."""
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.store import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.distributed.fault import (FailureInjector, InjectedFailure,
                                     StragglerWatchdog, Supervisor)


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"a": jnp.asarray(rng.normal(size=(4, 4)), jnp.bfloat16),
            "stages": [(jnp.arange(6).reshape(2, 3),
                        jnp.asarray(rng.normal(size=(5,)), jnp.float32))],
            "step": jnp.asarray(7, jnp.int32)}
    save_checkpoint(str(tmp_path), 10, tree)
    assert latest_step(str(tmp_path)) == 10
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomicity(tmp_path):
    """A stale .tmp dir from a crashed save never shadows the real one."""
    tree = {"x": jnp.ones((2,))}
    save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert latest_step(str(tmp_path)) == 1
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 1


def test_checkpoint_quantized_roundtrip(tmp_path, rng):
    """QLinear pytrees round-trip through the leaf store transparently."""
    from repro.core.qlinear import QuantConfig, quantize_linear
    w = jnp.asarray(rng.normal(size=(128, 32)) * 0.02, jnp.float32)
    q = quantize_linear(w, None, QuantConfig(ratio=0.25, multiple=16))
    save_checkpoint(str(tmp_path), 3, {"lin": q})
    restored, _ = restore_checkpoint(str(tmp_path), {"lin": q})
    np.testing.assert_array_equal(np.asarray(restored["lin"].bits),
                                  np.asarray(q.bits))
    np.testing.assert_allclose(np.asarray(restored["lin"].to_dense(),
                                          np.float32),
                               np.asarray(q.to_dense(), np.float32))


def test_supervisor_restart_path(tmp_path):
    calls = []
    state = {"v": 0}
    inj = FailureInjector(fail_at_steps=(3,))

    def restore():
        state["v"] = 2           # checkpointed value at step 2
        return 2

    def step(i):
        inj.maybe_fail(i)
        state["v"] = i + 1
        calls.append(i)

    sup = Supervisor(restore, max_restarts=2, log=lambda *_: None)
    end = sup.run(step, 0, 6)
    assert end == 6
    assert sup.restarts == 1
    # the failure fires BEFORE step 3's work is recorded; restore()
    # returns 2 (= steps completed at the checkpoint), so the supervisor
    # replays step 2 and then completes 3..5
    assert calls == [0, 1, 2, 2, 3, 4, 5]
    assert state["v"] == 6


def test_supervisor_gives_up():
    inj = FailureInjector(fail_at_steps=(1,))

    def step(i):
        if i == 1:
            raise InjectedFailure("always")

    sup = Supervisor(lambda: 1, max_restarts=2, log=lambda *_: None)
    with pytest.raises(InjectedFailure):
        sup.run(step, 0, 4)


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=3.0)
    logs = []
    for i in range(20):
        wd.observe(i, 0.01, log=logs.append)
    wd.observe(20, 0.5, log=logs.append)
    assert wd.slow_steps == [20]
    assert len(logs) == 1


def test_train_restart_bit_determinism(tmp_path):
    """Crash + restore reproduces the exact same final loss as an
    uninterrupted run (pure-function-of-step data order)."""
    from repro.launch.train import parse_args, run

    common = ["--arch", "tiny-lm", "--reduced", "--steps", "12",
              "--batch", "2", "--seq", "32", "--log-every", "100",
              "--save-every", "4"]
    r1 = run(parse_args(common + ["--ckpt-dir", str(tmp_path / "a")]))
    r2 = run(parse_args(common + ["--ckpt-dir", str(tmp_path / "b"),
                                  "--fail-at-step", "9"]))
    assert r2["restarts"] == 1
    assert r1["final_loss"] == pytest.approx(r2["final_loss"], abs=1e-5)


def test_elastic_restore_into_template(tmp_path, rng):
    """Checkpoints restore into any matching-shape template (re-mesh:
    arrays are stored unsharded per leaf)."""
    tree = {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
    save_checkpoint(str(tmp_path), 5, tree)
    template = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    restored, _ = restore_checkpoint(str(tmp_path), template)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    bad = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)
