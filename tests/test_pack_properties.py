"""Hypothesis property tests for packing and quantization invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import binarize, int4, pack
from repro.core.saliency import round_salient, structured_mask

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(1, 8).map(lambda i: i * 8), st.integers(1, 24),
       st.integers(0, 2**31 - 1))
def test_pack_bits_roundtrip(k, n, seed):
    rng = np.random.default_rng(seed)
    signs = rng.choice([-1.0, 1.0], size=(k, n)).astype(np.float32)
    packed = pack.pack_bits(jnp.asarray(signs), axis=-2)
    assert packed.shape == (k // 8, n) and packed.dtype == jnp.uint8
    out = pack.unpack_bits(packed, axis=-2, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), signs)


@given(st.integers(1, 12).map(lambda i: i * 2), st.integers(1, 24),
       st.integers(0, 2**31 - 1))
def test_pack_nibbles_roundtrip(k, n, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 16, size=(k, n)).astype(np.uint8)
    packed = pack.pack_nibbles(jnp.asarray(q), axis=-2)
    assert packed.shape == (k // 2, n)
    out = pack.unpack_nibbles(packed, axis=-2, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), q.astype(np.float32))


@given(st.integers(2, 6).map(lambda i: i * 8), st.integers(2, 16),
       st.integers(0, 2**31 - 1))
def test_stacked_pack_roundtrip(k, n, seed):
    """(L, K, N) stacked weights pack identically per slice."""
    rng = np.random.default_rng(seed)
    signs = rng.choice([-1.0, 1.0], size=(3, k, n)).astype(np.float32)
    packed = pack.pack_bits(jnp.asarray(signs), axis=-2)
    assert packed.shape == (3, k // 8, n)
    for i in range(3):
        one = pack.pack_bits(jnp.asarray(signs[i]), axis=-2)
        np.testing.assert_array_equal(np.asarray(packed[i]), np.asarray(one))


@given(st.integers(4, 64), st.integers(4, 32), st.integers(0, 2**31 - 1))
def test_int4_dequant_error_bound(k, n, seed):
    """|w − dq(q(w))| ≤ 2·s per element on zero-SPANNING rows (s/2
    round-to-nearest + s/2 zero-point rounding + ≤s clipped extreme
    level).  Single-signed rows clamp the zero-point and lose the bound
    — irrelevant for weight rows, which span zero, but excluded here."""
    rng = np.random.default_rng(seed)
    wn = rng.normal(size=(k, n)).astype(np.float32)
    wn[:, 0] = -np.abs(wn[:, 0]) - 0.1   # force both signs per row
    wn[:, 1] = +np.abs(wn[:, 1]) + 0.1
    w = jnp.asarray(wn)
    d = int4.quantize_int4(w)
    back = int4.dequant_int4(d["q"], d["s"], d["z"], dtype=jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(w))
    bound = 2.0 * np.asarray(d["s"])[:, None] + 1e-5
    assert (err <= bound + 1e-6).all()


@given(st.integers(4, 64), st.integers(4, 32), st.integers(0, 2**31 - 1))
def test_binarize_alpha_is_l1_optimal(k, n, seed):
    """α = mean|w| minimizes ‖w − α·sign(w)‖² over α (XNOR-Net lemma):
    perturbing α in either direction never reduces the error."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, n)).astype(np.float32)
    b = binarize.binarize_init(jnp.asarray(w))
    alpha = np.asarray(b["alpha_s"])
    sign = np.sign(w) + (w == 0)
    base = ((w - alpha[None, :] * sign) ** 2).sum(0)
    for eps in (0.99, 1.01):
        pert = ((w - (alpha * eps)[None, :] * sign) ** 2).sum(0)
        assert (pert >= base - 1e-5).all()


@given(st.integers(128, 4096), st.floats(0.05, 0.45),
       st.sampled_from([16, 64, 128]))
def test_round_salient_bounds(k, ratio, multiple):
    if k <= 2 * multiple:
        return
    k_s = round_salient(k, ratio, multiple)
    assert multiple <= k_s <= k - multiple
    assert k_s % multiple == 0


@given(st.integers(2, 32), st.integers(0, 2**31 - 1))
def test_structured_mask_permutation(k8, seed):
    """perm is a permutation; salient channels (top-k_s by stat) come
    first in original relative order."""
    k = k8 * 16
    rng = np.random.default_rng(seed)
    sal = jnp.asarray(rng.uniform(0, 10, k).astype(np.float32))
    mask, perm, k_s = structured_mask(sal, 0.25, 16)
    perm = np.asarray(perm)
    mask = np.asarray(mask)
    assert sorted(perm.tolist()) == list(range(k))
    assert mask.sum() == k_s
    # first k_s entries of perm are exactly the masked channels, ordered
    front = perm[:k_s]
    assert mask[front].all()
    assert (np.diff(front) > 0).all()
    # they really are the top-k_s by saliency
    thresh = np.sort(np.asarray(sal))[-k_s]
    assert (np.asarray(sal)[front] >= thresh - 1e-6).all()


@given(st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_qlinear_todense_roundtrip(scale, seed):
    """to_dense() inverts the salient-first permutation exactly, and the
    binary part reconstructs α·sign at init (α_r = 1)."""
    from repro.core.qlinear import QuantConfig, quantize_linear
    rng = np.random.default_rng(seed)
    k, n = 64 * scale, 32
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * 0.1)
    stat = jnp.asarray(rng.uniform(0.1, 5.0, k).astype(np.float32))
    q = quantize_linear(w, stat, QuantConfig(ratio=0.25, multiple=16))
    dense = np.asarray(q.to_dense(jnp.float32))
    assert dense.shape == (k, n)
    # non-salient rows must equal α·sign(w) exactly
    perm = np.asarray(q.perm)
    wnp = np.asarray(w)
    alpha = np.asarray(q.alpha_s)
    for i in perm[q.k_s:]:
        expect = alpha * np.sign(wnp[i] + (wnp[i] == 0))
        np.testing.assert_allclose(dense[i], expect, rtol=1e-2, atol=1e-3)
