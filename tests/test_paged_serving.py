"""Paged serving runtime: allocator, scheduler, engine edge cases.

Covers the acceptance surface of the paged KV subsystem: block-pool
bookkeeping, FCFS admission order, paged-vs-contiguous greedy
equivalence, prompts longer than the largest prefill bucket, and the
pool-exhaustion → preemption → completion path.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import model as M
from repro.models.common import Parallel
from repro.runtime.engine import Engine, _sample_batched
from repro.runtime.metrics import EngineMetrics
from repro.runtime.paged_cache import (BlockTables, PagePool,
                                       pages_for_tokens)
from repro.runtime.scheduler import Scheduler, SchedulerConfig

PAR = Parallel(remat=False, attn_chunk=32)


@pytest.fixture(scope="module")
def subject():
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    return cfg, params


def make_engine(subject, *, paged, n_slots=2, max_seq=64, **kw):
    cfg, params = subject
    return Engine(cfg, PAR, params, n_slots=n_slots, max_seq=max_seq,
                  prefill_buckets=(16, 32), paged=paged, **kw)


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------
def test_pages_for_tokens():
    assert pages_for_tokens(0, 8) == 0
    assert pages_for_tokens(1, 8) == 1
    assert pages_for_tokens(8, 8) == 1
    assert pages_for_tokens(9, 8) == 2


def test_pool_alloc_free_reuse():
    pool = PagePool(num_pages=4, page_size=8)
    a = pool.alloc(3)
    assert len(a) == 3 and pool.free_pages == 1
    assert pool.alloc(2) is None            # no partial allocation
    assert pool.free_pages == 1
    pool.free(a[:2])
    b = pool.alloc(3)
    assert b is not None and pool.pages_in_use == 4
    with pytest.raises(ValueError):
        pool.free(a[:1] + a[:1])            # double free detected
    st = pool.stats()
    assert st.alloc_failures == 1 and st.peak_in_use == 4


def test_block_tables_grow_and_release():
    pool = PagePool(num_pages=6, page_size=8)
    bt = BlockTables(pool, n_slots=2, max_blocks=4)
    assert bt.ensure_for_position(0, 17)    # needs blocks 0..2
    assert bt.n_blocks(0) == 3
    row = bt.as_array()[0]
    assert (row[:3] >= 0).all() and row[3] == -1
    assert bt.ensure_blocks(1, 3)
    assert not bt.ensure_blocks(0, 4)   # pool exhausted: refused...
    assert bt.n_blocks(0) == 3          # ...with no partial allocation
    assert bt.release(1) == 3
    assert pool.free_pages == 3
    assert (bt.as_array()[1] == -1).all()


# ---------------------------------------------------------------------------
# Scheduler policy
# ---------------------------------------------------------------------------
class _Req:
    def __init__(self, rid, need_toks=8, deadline_t=None):
        self.rid, self.deadline_t, self.admit_seq = rid, deadline_t, 0
        self._need = need_toks

    def n_prompt_tokens(self):
        return self._need


def test_scheduler_fcfs_head_of_line():
    s = Scheduler()
    s.enqueue(_Req(1, need_toks=100))       # head needs 13 pages
    s.enqueue(_Req(2, need_toks=4))         # would fit, but FCFS: blocked
    assert s.next_admissible(free_pages=2, page_size=8) is None
    got = s.next_admissible(free_pages=None, page_size=8)
    assert got.rid == 1                     # contiguous backend: always fits


def test_scheduler_victim_policies():
    reqs = {0: _Req(1), 1: _Req(2), 2: _Req(3)}
    for slot, r in reqs.items():
        r.admit_seq = slot + 1
    s_new = Scheduler(SchedulerConfig(preempt_policy="newest"))
    s_old = Scheduler(SchedulerConfig(preempt_policy="oldest"))
    assert s_new.choose_victim(reqs) == 2
    assert s_old.choose_victim(reqs) == 0
    assert s_new.choose_victim(reqs, exclude=2) == 1
    assert s_new.choose_victim({0: reqs[0]}, exclude=0) == 0  # self if alone


def test_scheduler_deadlines():
    t = [0.0]
    s = Scheduler(clock=lambda: t[0])
    s.enqueue(_Req(1, deadline_t=5.0))
    s.enqueue(_Req(2))                      # no deadline
    started = _Req(3, deadline_t=5.0)       # preempted mid-flight:
    started.admit_seq = 1                   # already admitted once
    s.enqueue(started, front=True)
    t[0] = 10.0
    dead = s.expire()
    # only the never-admitted request expires; the preempted one keeps
    # its place (work already paid for — see Scheduler.expire)
    assert [r.rid for r in dead] == [1] and len(s) == 2


# ---------------------------------------------------------------------------
# Engine: paged vs contiguous equivalence and edge cases
# ---------------------------------------------------------------------------
def test_paged_matches_contiguous_greedy(subject):
    """Temperature 0: same tokens from both backends, requests > slots.

    Dedicated rng (not the shared session fixture): on an untrained
    model, near-tied bf16 logits can flip argmax between the scan-based
    contiguous decode and the unrolled paged decode for *some* prompt
    sets; this seed is a verified tie-free workload, which is exactly
    the regime the equivalence claim is about (see the analogous caveat
    in test_runtime.test_engine_greedy_matches_decode_reference).

    paged_kernel=False: this test's claim is the paged BOOKKEEPING
    (block tables, splice, masks) against the contiguous oracle, so both
    sides must share the XLA attention numerics — the flash-decode
    kernel rounds differently at the bf16 ulp and is held to greedy
    identity on its own margin-verified workload in
    test_paged_attention.py."""
    cfg, _ = subject
    local = np.random.default_rng(0)
    prompts = [local.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 9, 13, 7, 21)]

    def run(paged):
        eng = make_engine(subject, paged=paged, page_size=8,
                          paged_kernel=False)
        reqs = [eng.submit(p, max_new=6) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs]

    assert run(False) == run(True)


@pytest.mark.parametrize("paged", [False, True])
def test_engine_fused_projections_greedy_identical(subject, paged):
    """Decode fast path acceptance: serving with N-fused QKV / gate+up
    projections (Engine(fuse_projections=True)) must emit EXACTLY the
    greedy tokens of the per-projection oracle engine — fp fusion is
    pure concatenation, so any token drift is a fusion bug.  Uses the
    same verified tie-free workload as the backend-equivalence test."""
    cfg, _ = subject
    local = np.random.default_rng(0)
    prompts = [local.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 9, 13, 7, 21)]

    def run(fused):
        eng = make_engine(subject, paged=paged, page_size=8,
                          fuse_projections=fused)
        if fused:
            attn0 = eng.params["stages"][0][0]["attn"]
            assert "wqkv" in attn0 and "wq" not in attn0
        reqs = [eng.submit(p, max_new=6) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs]

    assert run(False) == run(True)


def test_engine_phase_step_timing(subject, rng):
    """Per-phase timing lands in the metrics snapshot: each compiled
    shape's first call is split into "<phase>_compile" so the base
    prefill/decode series are steady-state only."""
    cfg, _ = subject
    eng = make_engine(subject, paged=True, page_size=8)
    reqs = [eng.submit(rng.integers(1, cfg.vocab, size=n).astype(np.int32),
                       max_new=4) for n in (6, 20)]   # two prefill buckets
    eng.run()
    assert all(r.done for r in reqs)
    phases = eng.metrics.snapshot()["phase_step_s"]
    # one compile sample per bucket shape; steady prefills only for
    # shapes prefilled more than once (none here)
    assert phases["prefill_compile"]["count"] == 2
    assert phases["decode_compile"]["count"] == 1
    assert phases["decode"]["count"] >= 2
    assert 0 < phases["decode"]["mean_s"] <= phases["decode"]["p95_s"]
    # the compile call dwarfs a steady decode step on this subject
    assert phases["decode_compile"]["mean_s"] > phases["decode"]["mean_s"]


def test_queue_drain_order_fcfs(subject, rng):
    """More requests than slots: admission follows submission order."""
    cfg, _ = subject
    eng = make_engine(subject, paged=True, n_slots=2, page_size=8)
    reqs = [eng.submit(rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                       max_new=4) for _ in range(6)]
    eng.run()
    assert all(r.done for r in reqs)
    seqs = [r.admit_seq for r in reqs]
    assert seqs == sorted(seqs)             # FCFS: rid order == admit order
    assert eng.metrics.snapshot()["queue_depth_max"] >= 1


@pytest.mark.parametrize("paged", [False, True])
def test_prompt_longer_than_largest_bucket(subject, rng, paged):
    """Prompts past the largest prefill bucket are left-truncated and
    still decode to completion."""
    cfg, _ = subject
    eng = make_engine(subject, paged=paged, page_size=8)
    long_prompt = rng.integers(1, cfg.vocab, size=50).astype(np.int32)
    r = eng.submit(long_prompt, max_new=5)
    assert len(r.prompt) == 32              # largest bucket
    np.testing.assert_array_equal(r.prompt, long_prompt[-32:])
    eng.run()
    assert r.done and len(r.out_tokens) == 5


def test_pool_exhaustion_preemption_completion(subject, rng):
    """Tight pool: decode growth exhausts pages, a victim is preempted
    and re-queued, and every request still completes."""
    cfg, _ = subject
    eng = make_engine(subject, paged=True, page_size=8, pool_pages=6)
    reqs = [eng.submit(rng.integers(1, cfg.vocab, size=13).astype(np.int32),
                       max_new=20) for _ in range(3)]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 20 for r in reqs)
    snap = eng.metrics.snapshot()
    assert snap["preemptions"] >= 1
    assert sum(r.preemptions for r in reqs) == snap["preemptions"]
    assert eng.backend.pool.pages_in_use == 0       # all pages returned


@pytest.mark.parametrize("paged", [False, True])
def test_prompt_fills_whole_bucket(subject, rng, paged):
    """A prompt as long as max_seq must not place the first decode write
    at position max_seq (past every cache layout): prompts cap at
    max_seq - 1 and the request still completes."""
    cfg, params = subject
    eng = Engine(cfg, PAR, params, n_slots=1, max_seq=32,
                 prefill_buckets=(32,), paged=paged, page_size=8)
    r = eng.submit(rng.integers(1, cfg.vocab, size=32).astype(np.int32),
                   max_new=4)
    assert len(r.prompt) == 31              # max_seq - 1
    eng.run()
    assert r.done and len(r.out_tokens) >= 1


def test_resume_page_need_capped_by_prompt_cap():
    """Admission gating must use the same truncation _start applies:
    a long-generating preempted request's page need is capped."""
    from repro.runtime.engine import Request
    r = Request(1, np.arange(8, dtype=np.int32), prompt_cap=32,
                out_tokens=list(range(60)))
    assert r.n_prompt_tokens() == 32


def test_submit_rejects_impossible_request(subject, rng):
    cfg, _ = subject
    eng = make_engine(subject, paged=True, page_size=8, pool_pages=2)
    with pytest.raises(ValueError):
        eng.submit(rng.integers(1, cfg.vocab, size=20).astype(np.int32),
                   max_new=20)
def test_max_new_limits_respected(subject, rng):
    """max_new=0 completes with no tokens (never queued); max_new=1
    finishes at prefill without entering decode (exactly one token)."""
    cfg, _ = subject
    eng = make_engine(subject, paged=True, page_size=8)
    r0 = eng.submit(rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                    max_new=0)
    r1 = eng.submit(rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                    max_new=1)
    eng.run()
    assert r0.done and r0.out_tokens == []
    assert r1.done and len(r1.out_tokens) == 1
    assert eng.backend.pool.pages_in_use == 0   # prefill pages released
    # queue of instant-finishing requests beyond the slot count: each
    # prefill leaves its slot free, so admission must keep draining the
    # queue instead of reporting a stuck tick (regression: RuntimeError)
    eng2 = make_engine(subject, paged=True, page_size=8)
    more = [eng2.submit(rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                        max_new=1) for _ in range(5)]
    eng2.run()
    assert all(m.done and len(m.out_tokens) == 1 for m in more)


def test_paged_matches_contiguous_hybrid_arch():
    """Recurrent (rglru) + sliding-window (local) blocks through the
    paged engine: recurrent state splices per-slot, windowed attention
    masks stale pages — tokens must match the contiguous backend.

    paged_kernel=False for the same reason as
    test_paged_matches_contiguous_greedy: shared XLA numerics isolate
    the bookkeeping claim; kernel-vs-reference identity (incl. the
    sliding window) lives in test_paged_attention.py."""
    cfg = registry.get("recurrentgemma-2b").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    local = np.random.default_rng(0)
    prompts = [local.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 11, 17)]

    def run(paged):
        eng = Engine(cfg, PAR, params, n_slots=2, max_seq=64,
                     prefill_buckets=(16, 32), paged=paged, page_size=8,
                     paged_kernel=False)
        reqs = [eng.submit(p, max_new=4) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs]

    assert run(False) == run(True)


def test_deadline_expires_queued_request(subject, rng):
    cfg, _ = subject
    eng = make_engine(subject, paged=False, n_slots=1)
    a = eng.submit(rng.integers(1, cfg.vocab, size=8).astype(np.int32),
                   max_new=20)
    b = eng.submit(rng.integers(1, cfg.vocab, size=8).astype(np.int32),
                   max_new=4, deadline_s=0.0)
    eng.run()
    assert a.done and not a.expired and len(a.out_tokens) == 20
    assert b.expired and b.out_tokens == []
    assert eng.metrics.snapshot()["expirations"] == 1


# ---------------------------------------------------------------------------
# Vectorized sampling
# ---------------------------------------------------------------------------
def test_sample_batched_greedy_and_stochastic(rng):
    logits = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    key = jax.random.PRNGKey(0)
    temps = jnp.asarray([0.0, 0.0, 1.0, 1.0], jnp.float32)
    toks = np.asarray(_sample_batched(logits, key, temps))
    ref = np.argmax(np.asarray(logits), axis=-1)
    np.testing.assert_array_equal(toks[:2], ref[:2])    # greedy lanes
    assert ((0 <= toks) & (toks < 32)).all()
    # greedy lanes ignore the key entirely
    toks2 = np.asarray(_sample_batched(logits, jax.random.PRNGKey(7), temps))
    np.testing.assert_array_equal(toks[:2], toks2[:2])


def test_paged_metrics_sanity(subject, rng):
    cfg, _ = subject
    clock = iter(np.arange(0.0, 1000.0, 0.5))
    eng = make_engine(subject, paged=True, page_size=8,
                      metrics=EngineMetrics(clock=lambda: next(clock)))
    r = eng.submit(rng.integers(1, cfg.vocab, size=9).astype(np.int32),
                   max_new=8)
    eng.run()
    snap = eng.metrics.snapshot()
    assert r.done and snap["generated_tokens"] == 8
    assert snap["ttft_mean_s"] > 0 and snap["tokens_per_s"] > 0
    assert 0 < snap["page_util_max"] <= 1.0
    assert snap["completed"] == 1
