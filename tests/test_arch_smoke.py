"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED same-family config runs one forward/loss, one train step, one
prefill+decode step on CPU with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import cell_applicable, cell_by_name
from repro.models import model as M
from repro.models.common import Parallel

PAR = Parallel(tp=1, dp=1, remat=False, attn_chunk=32)
ARCHS = registry.ASSIGNED + ["llama-7b"]


def make_batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "targets": jnp.ones((b, s), jnp.int32)}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.zeros(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = 0.1 * jnp.ones((b, 8, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def models():
    cache = {}
    for name in ARCHS:
        cfg = registry.get(name).reduced()
        params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
        cache[name] = (cfg, params)
    return cache


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(models, arch):
    cfg, params = models[arch]
    loss = M.forward_loss(cfg, PAR, params, make_batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_improves_or_finite(models, arch):
    from repro.distributed.compression import CompressionConfig
    from repro.launch.train import make_train_step
    from repro.optim.adamw import AdamW

    cfg, params = models[arch]
    # clip_norm matches the production launcher — without it repeated
    # full-batch steps can blow up the sLSTM gates into inf/NaN
    opt = AdamW(lr=1e-3, clip_norm=1.0)
    step = make_train_step(cfg, PAR, opt, CompressionConfig())
    state = {"params": params, "opt": opt.init(params),
             "residual": jnp.zeros((), jnp.float32)}
    batch = make_batch(cfg)
    losses = []
    for _ in range(4):
        state, m = step(state, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss)
        losses.append(loss)
    # repeated steps on the same batch must dip below the starting loss
    # at some point.  Not losses[-1] < losses[0]: the xlstm trajectory
    # bumps up around step 2 before clipped AdamW pulls it down, so the
    # final/initial margin is within noise — and more steps risk sLSTM
    # gate blow-up.  Historical note: this test flaked ~50% with
    # non-finite losses for YEARS of PRs because materialize() derived
    # per-leaf init keys from the builtin (per-process randomized)
    # hash() — every process trained from DIFFERENT initial weights and
    # some draws blew up.  With the crc32 path hash in
    # repro.models.param the trajectory is identical in every process
    # and this assertion is deterministic.
    assert min(losses[1:]) < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(models, arch):
    """Prefill on [t0..t_{n}] then decode t_{n+1} must equal prefill on
    the longer sequence's last-token logits (cache correctness).

    MoE archs use a no-drop capacity factor here: token-choice capacity
    dropping legitimately differs between a 1-token decode call and a
    full-sequence prefill (standard Switch/Mixtral semantics)."""
    cfg, params = models[arch]
    if cfg.moe is not None:
        import dataclasses
        from repro.configs.base import MoEConfig
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    b, s, max_seq = 2, 16, 32
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(1, cfg.vocab - 1, (b, s + 1)), jnp.int32)

    batch = dict(make_batch(cfg, b, s))
    batch["tokens"] = toks[:, :s]
    batch.pop("targets")
    logits, caches = M.prefill(cfg, PAR, params, batch, max_seq)
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab_padded

    step_logits, _ = M.decode_step(cfg, PAR, params, toks[:, s],
                                   jnp.full((b,), s, jnp.int32), caches,
                                   max_seq)
    assert step_logits.shape == (b, cfg.vocab_padded)

    batch2 = dict(make_batch(cfg, b, s + 1))
    batch2["tokens"] = toks
    batch2.pop("targets")
    ref_logits, _ = M.prefill(cfg, PAR, params, batch2, max_seq)
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(ref_logits[:, -1], np.float32), rtol=0.15, atol=0.25)


@pytest.mark.parametrize("arch", ARCHS)
def test_cell_applicability_matrix(arch):
    """long_500k runs iff the arch is sub-quadratic (DESIGN.md §4)."""
    cfg = registry.get(arch)
    ok, why = cell_applicable(cfg, cell_by_name("long_500k"))
    expect = arch in ("xlstm-1.3b", "recurrentgemma-2b", "mixtral-8x22b")
    assert ok == expect, (arch, why)
    for cell in ("train_4k", "prefill_32k", "decode_32k"):
        ok, _ = cell_applicable(cfg, cell_by_name(cell))
        assert ok


@pytest.mark.parametrize("arch", ARCHS)
def test_quantize_data_free(models, arch):
    """PTQ1.61 data-free quantization applies to every architecture and
    keeps the forward finite (DESIGN.md §Arch-applicability)."""
    from repro.core.pipeline import quantize_params_data_free
    from repro.core.qlinear import QLinear, QuantConfig

    cfg, params = models[arch]
    qp = quantize_params_data_free(
        params, QuantConfig(ratio=0.25, multiple=16), min_dim=32)
    n_q = len([l for l in jax.tree.leaves(
        qp, is_leaf=lambda x: isinstance(x, QLinear))
        if isinstance(l, QLinear)])
    assert n_q > 0, "no quantizable leaves found"
    loss = M.forward_loss(cfg, PAR, qp, make_batch(cfg))
    assert np.isfinite(float(loss))
