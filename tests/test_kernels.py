"""Pallas kernel sweeps: every kernel × shapes × dtypes against the
pure-jnp oracle in repro.kernels.ref (interpret mode on CPU).

Tolerances: the kernels feed bf16 operands to the MXU (jax.lax.dot with
f32 accumulation) while the oracle contracts in f32, so per-element
relative error scales like 2^-8·sqrt(K); assertions use an explicit
K-scaled atol on top of 2% rtol.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pack
from repro.kernels import ref
from repro.kernels.binary_matmul import binary_matmul
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.mixed_matmul import mixed_matmul


def _tol(k, scale=1.0):
    return {"rtol": 2e-2, "atol": 0.06 * np.sqrt(k) * scale}


def make_binary(rng, k, n):
    signs = rng.choice([-1.0, 1.0], size=(k, n)).astype(np.float32)
    bits = pack.pack_bits(jnp.asarray(signs), axis=-2)
    a_out = jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32)
    a_in = jnp.asarray(rng.uniform(0.5, 2.0, k), jnp.float32)
    return bits, a_out, a_in


def make_int4(rng, k, n):
    q = jnp.asarray(rng.integers(0, 16, size=(k, n)), jnp.uint8)
    w4 = pack.pack_nibbles(q, axis=-2)
    s4 = jnp.asarray(rng.uniform(0.01, 0.1, k), jnp.float32)
    z4 = jnp.asarray(rng.integers(0, 16, k).astype(np.float32))
    return w4, s4, z4


@pytest.mark.parametrize("m,k,n", [
    (8, 128, 128), (128, 256, 256), (64, 512, 384),
    (256, 1024, 512), (32, 2048, 128),
])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_binary_matmul(rng, m, k, n, dtype):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    bits, a_out, a_in = make_binary(rng, k, n)
    y_ref = ref.binary_matmul_ref(x, bits, a_out, a_in).astype(np.float32)
    y = binary_matmul(x, bits, a_out, a_in, interpret=True).astype(np.float32)
    np.testing.assert_allclose(y, y_ref, **_tol(k, 2.0))


@pytest.mark.parametrize("m,k,n", [
    (8, 128, 128), (128, 256, 256), (64, 512, 384), (16, 1024, 256),
])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_int4_matmul(rng, m, k, n, dtype):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w4, s4, z4 = make_int4(rng, k, n)
    y_ref = ref.int4_matmul_ref(x, w4, s4, z4).astype(np.float32)
    y = int4_matmul(x, w4, s4, z4, interpret=True).astype(np.float32)
    np.testing.assert_allclose(y, y_ref, **_tol(k))


@pytest.mark.parametrize("m,k_s,k_b,n", [
    (8, 128, 384, 128), (64, 128, 512, 256),
    (128, 256, 1024, 256), (32, 512, 512, 384),
])
def test_mixed_matmul(rng, m, k_s, k_b, n):
    k = k_s + k_b
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
    w4, s4, z4 = make_int4(rng, k_s, n)
    bits, a_out, a_in = make_binary(rng, k_b, n)
    y_ref = ref.mixed_matmul_ref(x, w4, s4, z4, bits, a_out, a_in)
    y = mixed_matmul(x, w4, s4, z4, bits, a_out, a_in, interpret=True)
    np.testing.assert_allclose(y.astype(np.float32),
                               y_ref.astype(np.float32), **_tol(k, 2.0))


def test_mixed_matches_qlinear_forward(rng):
    """ops.mixed_matmul(x, qlinear) == the XLA dequant forward."""
    from repro.core.qlinear import QuantConfig, quantize_linear
    from repro.kernels import ops
    import dataclasses

    k, n = 640, 256
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    stat = jnp.asarray(rng.uniform(0.1, 10.0, k), jnp.float32)
    q = quantize_linear(w, stat, QuantConfig(ratio=0.2, multiple=128,
                                             use_kernel=False))
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.bfloat16)
    y_xla = q.__matmul_x__(x).astype(np.float32)
    y_ker = ops.mixed_matmul(x, q).astype(np.float32)
    np.testing.assert_allclose(y_ker, y_xla, rtol=2e-2,
                               atol=0.06 * np.sqrt(k))


def test_mixed_matmul_gather_in_kernel_bit_identical(rng):
    """The scalar-prefetched perm path (gather inside the kernel, full-K
    x tile) is pure data movement: results must be BIT-identical to
    pre-gathering the activation on the host."""
    m, k_s, k_b, n = 8, 128, 384, 128
    k = k_s + k_b
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
    w4, s4, z4 = make_int4(rng, k_s, n)
    bits, a_out, a_in = make_binary(rng, k_b, n)
    perm = jnp.asarray(rng.permutation(k), jnp.int32)
    xp = jnp.take(x, perm, axis=-1)
    y_pre = mixed_matmul(xp, w4, s4, z4, bits, a_out, a_in, interpret=True)
    y_ker = mixed_matmul(x, w4, s4, z4, bits, a_out, a_in, perm,
                         interpret=True)
    np.testing.assert_array_equal(np.asarray(y_ker, np.float32),
                                  np.asarray(y_pre, np.float32))


def test_ops_mixed_matmul_uses_in_kernel_gather(rng):
    """ops.mixed_matmul routes the decode-shaped QLinear forward through
    the in-kernel gather (no host-side permuted copy of x) and still
    matches the XLA dequant oracle."""
    from repro.core.qlinear import QuantConfig, quantize_linear
    from repro.kernels import autotune, ops

    k, n = 640, 256
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    stat = jnp.asarray(rng.uniform(0.1, 10.0, k), jnp.float32)
    q = quantize_linear(w, stat, QuantConfig(ratio=0.2, multiple=128))
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.bfloat16)
    choice = autotune.choose_blocks(4, q.k_s, q.k_b, q.n)
    assert autotune.gather_in_kernel_ok(choice, 4, k)   # decode M: fits
    y_ker = ops.mixed_matmul(x, q).astype(np.float32)
    y_xla = q.__matmul_x__(x).astype(np.float32)
    np.testing.assert_allclose(y_ker, y_xla, rtol=2e-2,
                               atol=0.06 * np.sqrt(k))
    # huge-K prefill shapes that overflow the full-K tile budget fall
    # back to the host-side gather, never to a wrong answer
    assert not autotune.gather_in_kernel_ok(choice, 4, k,
                                            vmem_budget=1 << 12)


def test_mixed_matmul_mismatched_k_spans(rng):
    """k_s=128, k_b=192: no single bk ≤ 128 divides both spans at the old
    default — the kernel must repair bk to the common divisor (64), not
    assert mid-trace."""
    m, k_s, k_b, n = 8, 128, 192, 128
    k = k_s + k_b
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
    w4, s4, z4 = make_int4(rng, k_s, n)
    bits, a_out, a_in = make_binary(rng, k_b, n)
    y_ref = ref.mixed_matmul_ref(x, w4, s4, z4, bits, a_out, a_in)
    for blocks in ({}, {"bk": 128}):       # autotuned and explicit-cap
        y = mixed_matmul(x, w4, s4, z4, bits, a_out, a_in,
                         interpret=True, **blocks)
        np.testing.assert_allclose(y.astype(np.float32),
                                   y_ref.astype(np.float32), **_tol(k, 2.0))


# ---------------------------------------------------------------------------
# Block-size autotuner
# ---------------------------------------------------------------------------
def test_autotune_common_bk():
    from repro.kernels import autotune
    assert autotune.common_bk(128, 192) == 64
    assert autotune.common_bk(128, 136) == 8
    assert autotune.common_bk(512, 512) == 512
    assert autotune.common_bk(0, 384) == 384      # empty span: unconstrained
    assert autotune.common_bk(768, 3328, cap=128) == 128
    assert autotune.common_bk(24, 36) is None     # gcd 12: no ×8 divisor
    assert autotune.common_bk(0, 0) is None


@pytest.mark.parametrize("m,k_s,k_b,n", [
    (1, 768, 3328, 12288),     # llama-7b fused QKV at decode batch 1
    (4, 768, 3328, 22016),     # fused gate+up
    (16, 128, 512, 384),
    (256, 768, 3328, 4096),    # prefill-shaped
])
def test_autotune_choice_feasible(m, k_s, k_b, n):
    from repro.kernels import autotune
    c = autotune.choose_blocks(m, k_s, k_b, n)
    assert c is not None
    assert m % c.bm == 0 and n % c.bn == 0
    assert k_s % c.bk == 0 and k_b % c.bk == 0 and c.bk % 8 == 0
    assert c.vmem_bytes <= autotune.VMEM_BUDGET
    # decode shapes must stream the activation once: whole-M row block
    if m <= 16:
        assert c.bm == m


def test_autotune_decode_beats_legacy_blocks():
    """The picked tiling must not model MORE traffic than the legacy
    hard-coded (256, 512, 128) blocks on a decode shape."""
    from repro.kernels import autotune
    m, k_s, k_b, n = 4, 768, 3328, 12288
    c = autotune.choose_blocks(m, k_s, k_b, n)
    legacy = autotune.modeled_hbm_bytes(m, k_s, k_b, n,
                                        bm=min(256, m), bn=min(512, n))
    assert c.hbm_bytes <= legacy
    # one x read per call at decode shapes (bn covers all of N)
    assert c.bn == n


def test_autotune_knobs_are_live():
    """Reassigning the module knobs must take effect immediately, even
    for shapes already in the dispatch cache (knobs are cache keys)."""
    from repro.kernels import autotune
    shape = (4, 768, 3328, 12288)
    full = autotune.choose_blocks(*shape)
    assert full.bn == 12288
    old = autotune.BN_CAP
    try:
        autotune.BN_CAP = 512
        capped = autotune.choose_blocks(*shape)
        assert capped.bn <= 512
    finally:
        autotune.BN_CAP = old
    assert autotune.choose_blocks(*shape).bn == 12288
    # explicit budget overrides the module default
    tight = autotune.choose_blocks(*shape, vmem_budget=1 << 20)
    assert tight is None or tight.vmem_bytes <= 1 << 20


def test_autotune_unfeasible_shapes():
    from repro.kernels import autotune
    assert autotune.choose_blocks(4, 128, 512, 200) is None   # N % 128
    assert autotune.choose_blocks(4, 24, 36, 256) is None     # no common bk
    assert autotune.choose_blocks(0, 128, 512, 256) is None


def test_kernel_block_shape_sweep(rng):
    """Block-shape sweep: results must be block-size independent."""
    m, k, n = 128, 512, 256
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
    bits, a_out, a_in = make_binary(rng, k, n)
    base = binary_matmul(x, bits, a_out, a_in, bm=128, bn=128, bk=128,
                         interpret=True)
    for bm, bn, bk in [(64, 64, 64), (128, 256, 512), (32, 128, 256)]:
        y = binary_matmul(x, bits, a_out, a_in, bm=bm, bn=bn, bk=bk,
                          interpret=True)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(base, np.float32),
                                   rtol=1e-2, atol=0.5)
