"""Chunked paged-prefill: kernel-vs-XLA bit-exactness, chunked-vs-whole
identity, mid-prefill preemption, prefix compute-skipping and the
retention LRU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import ops
from repro.models import model as M
from repro.models.common import Parallel
from repro.runtime.engine import Engine
from repro.runtime.paged_cache import (BlockTables, PagePool, PrefixCache,
                                       pages_for_tokens)
from repro.runtime.scheduler import Scheduler

PAR = Parallel(tp=1, dp=1, remat=False, attn_chunk=32)


@pytest.fixture(scope="module")
def subject():
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Kernel vs XLA dense-gather fallback: bit-exact in f32
# ---------------------------------------------------------------------------
def _rand_case(rng, *, start, length, hkv=2, rep=2, dh=16, ps=4, c=8,
               nblk=8, pool_pages=12, mask_first_chunk_page=False):
    hq = hkv * rep
    pp = pool_pages + 1                          # + dump page
    k_pool = jnp.asarray(rng.normal(size=(2, pp, ps, hkv, dh)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(2, pp, ps, hkv, dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(c, hq, dh)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(c, hkv, dh)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(c, hkv, dh)), jnp.float32)
    n_pages = pages_for_tokens(start + length, ps)
    bt = np.full((nblk,), -1, np.int32)
    bt[:n_pages] = rng.permutation(pool_pages)[:n_pages]
    btw = bt.copy()
    if mask_first_chunk_page:                    # a shared (COW) block
        btw[start // ps] = -1
    return q, kn, vn, k_pool, v_pool, jnp.asarray(bt), jnp.asarray(btw)


@pytest.mark.parametrize("start,length,window,softcap", [
    (8, 8, None, None),          # full chunk over context
    (0, 8, None, None),          # first chunk, no context
    (8, 5, None, None),          # ragged tail (page-straddling)
    (16, 3, None, None),         # ragged, deeper context
    (8, 8, 5, None),             # sliding window
    (16, 7, 6, 30.0),            # window + softcap + ragged
])
def test_kernel_matches_xla_bit_exact(start, length, window, softcap):
    rng = np.random.default_rng(start * 100 + length)
    q, kn, vn, kp, vp, bt, btw = _rand_case(rng, start=start, length=length)
    ok, kk, vk = ops.paged_prefill(q, kn, vn, kp, vp, bt, btw, start,
                                   length, layer=1, window=window,
                                   softcap=softcap)
    ox, kx, vx = ops.paged_prefill_xla(q, kn, vn, kp, vp, bt, btw, start,
                                       length, layer=1, window=window,
                                       softcap=softcap)
    P = kp.shape[1] - 1
    assert bool(jnp.all(ok[:length] == ox[:length])), \
        "kernel output must match the dense-gather fallback bit-exactly"
    assert bool(jnp.all(kk[:, :P] == kx[:, :P]))
    assert bool(jnp.all(vk[:, :P] == vx[:, :P]))


@pytest.mark.parametrize("hkv,rep", [(1, 4), (2, 1), (4, 2)])
def test_kernel_gqa_ratios(hkv, rep):
    rng = np.random.default_rng(hkv * 10 + rep)
    q, kn, vn, kp, vp, bt, btw = _rand_case(rng, start=8, length=8,
                                            hkv=hkv, rep=rep)
    ok, kk, vk = ops.paged_prefill(q, kn, vn, kp, vp, bt, btw, 8, 8,
                                   layer=0)
    ox, kx, vx = ops.paged_prefill_xla(q, kn, vn, kp, vp, bt, btw, 8, 8,
                                       layer=0)
    P = kp.shape[1] - 1          # dump-page garbage differs by design
    assert bool(jnp.all(ok == ox))
    assert bool(jnp.all(kk[:, :P] == kx[:, :P]))
    assert bool(jnp.all(vk[:, :P] == vx[:, :P]))


def test_masked_write_row_preserves_shared_pages():
    """A shared (writable-row -1) chunk page must NOT be rewritten: its
    writes land on the dump page, attention still sees the recomputed
    in-chunk K/V, and untouched pool pages stay bit-identical."""
    rng = np.random.default_rng(3)
    q, kn, vn, kp, vp, bt, btw = _rand_case(rng, start=8, length=8,
                                            mask_first_chunk_page=True)
    ok, kk, vk = ops.paged_prefill(q, kn, vn, kp, vp, bt, btw, 8, 8,
                                   layer=0)
    ox, kx, vx = ops.paged_prefill_xla(q, kn, vn, kp, vp, bt, btw, 8, 8,
                                       layer=0)
    masked_page = int(np.asarray(bt)[8 // 4])
    assert bool(jnp.all(kk[:, masked_page] == kp[:, masked_page])), \
        "masked (shared) page content must survive the fused scatter"
    assert bool(jnp.all(ok == ox))
    assert bool(jnp.all(kk[:, :-1] == kx[:, :-1]))


def test_autotune_prefill_choice():
    from repro.kernels import autotune
    ch = autotune.choose_prefill_blocks(64, 4, 2, 128, 16)
    assert ch is not None and 4 % ch.bh == 0
    assert autotune.choose_prefill_blocks(60, 4, 2, 128, 16) is None, \
        "chunk must tile into pages"
    assert autotune.paged_prefill_read_bytes(32, 16, 16, 2, 16) == \
        (2 + 1) * 16 * autotune.paged_kv_bytes_per_token(2, 16)


# ---------------------------------------------------------------------------
# Model-level: chunked == whole-prompt prefill (f32 logits)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("plen", [5, 16, 23, 48, 61])
def test_chunked_matches_whole_prompt_logits(subject, plen):
    """Whole-prompt dense prefill vs the chunked paged path on an
    all-f32 model (bf16 params would make the two paths differ at the
    storage dtype, not in the chunking math)."""
    cfg, params = subject
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32)
        if isinstance(a, jax.Array) and a.dtype == jnp.bfloat16 else a,
        params)
    ps, chunk, max_seq = 8, 16, 128
    rng = np.random.default_rng(plen)
    seq = rng.integers(1, cfg.vocab, size=plen).astype(np.int32)

    # whole-prompt dense prefill -> last-token logits
    batch = {"tokens": jnp.asarray(seq[None]),
             "positions": jnp.arange(plen, dtype=jnp.int32)[None]}
    ref_logits, _ = M.prefill(cfg, PAR, params, batch, max_seq)

    # chunked paged prefill over a real block table
    pool = PagePool(32, ps)
    tables = BlockTables(pool, 1, pages_for_tokens(max_seq, ps))
    assert tables.ensure_blocks(0, pages_for_tokens(plen, ps))
    caches = M.init_paged_caches(cfg, PAR, 1, 32, ps, dtype=jnp.float32)
    from repro.models.param import materialize
    caches = materialize(caches, jax.random.PRNGKey(0))
    bt = jnp.asarray(tables.as_array()[0])
    logits = None
    for start in range(0, plen, chunk):
        length = min(chunk, plen - start)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :length] = seq[start:start + length]
        logits, caches = M.prefill_step_paged(
            cfg, PAR, params, jnp.asarray(toks), caches, bt, bt,
            start, length, max_seq=max_seq)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits[:, 0], np.float32),
                               rtol=2e-5, atol=2e-5)
    assert int(jnp.argmax(logits)) == int(jnp.argmax(ref_logits[:, 0]))


def test_engine_chunked_vs_whole_greedy_identity(subject):
    """Engine-level: ragged prompts, f32 pools — greedy outputs of the
    chunked engine are bit-identical to the whole-prompt engine's."""
    cfg, params = subject
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
               for n in (5, 17, 31, 48, 64, 97)]

    def run(**kw):
        eng = Engine(cfg, PAR, params, n_slots=3, max_seq=128,
                     prefill_buckets=(16, 64, 128), paged=True,
                     page_size=8, cache_dtype=jnp.float32, **kw)
        reqs = [eng.submit(p, max_new=8) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs], eng

    whole, _ = run()
    chunked, eng = run(chunked_prefill=True, prefill_chunk=32)
    assert whole == chunked
    snap = eng.metrics.snapshot()
    assert snap["prefill_chunks"] > 0
    assert "prefill" not in snap["phase_step_s"], \
        "chunked engine must never run the dense whole-prompt prefill"


def test_engine_chunked_quantized_greedy_identity(subject):
    cfg, params = subject
    from repro.core.pipeline import quantize_params_data_free
    from repro.core.qlinear import QuantConfig
    qp = quantize_params_data_free(params,
                                   QuantConfig(ratio=0.25, multiple=16),
                                   min_dim=32)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
               for n in (7, 29, 50)]

    def run(**kw):
        eng = Engine(cfg, PAR, qp, n_slots=2, max_seq=128,
                     prefill_buckets=(64, 128), paged=True, page_size=8,
                     cache_dtype=jnp.float32, **kw)
        reqs = [eng.submit(p, max_new=6) for p in prompts]
        eng.run()
        return [r.out_tokens for r in reqs]

    assert run() == run(chunked_prefill=True, prefill_chunk=16)


# ---------------------------------------------------------------------------
# Mid-prefill preemption + resume
# ---------------------------------------------------------------------------
def test_mid_prefill_preemption_resumes_identically(subject):
    cfg, params = subject
    rng = np.random.default_rng(21)
    short = rng.integers(1, cfg.vocab, size=8).astype(np.int32)
    long = rng.integers(1, cfg.vocab, size=90).astype(np.int32)

    def make():
        return Engine(cfg, PAR, params, n_slots=2, max_seq=128,
                      paged=True, page_size=8, cache_dtype=jnp.float32,
                      chunked_prefill=True, prefill_chunk=16)

    # clean run: no preemption
    eng = make()
    a0 = eng.submit(short, max_new=12)
    b0 = eng.submit(long, max_new=6)
    eng.run()
    assert b0.preemptions == 0

    # preempted run: evict the long request BETWEEN chunks, mid-prefill
    eng = make()
    a1 = eng.submit(short, max_new=12)
    b1 = eng.submit(long, max_new=6)
    for _ in range(3):
        eng.tick()
    slot_b = next(s for s, r in eng.running() if r.rid == b1.rid)
    st = eng._prefill_state[slot_b]
    assert 0 < st["frontier"] < len(long), "victim must be mid-prefill"
    slot_a = next(s for s, r in eng.running() if r.rid == a1.rid)
    assert eng._preempt_for(slot_a)      # newest-admitted victim = b1
    assert b1.preemptions == 1
    assert slot_b not in eng._prefill_state
    eng.run()
    assert a1.done and b1.done
    assert a1.out_tokens == a0.out_tokens
    assert b1.out_tokens == b0.out_tokens, \
        "mid-prefill preemption must resume to bit-identical greedy tokens"


# ---------------------------------------------------------------------------
# Prefix compute-skipping + retention LRU
# ---------------------------------------------------------------------------
def test_fully_shared_chunks_skip_kernel_calls(subject):
    cfg, params = subject
    ps, chunk = 8, 16
    rng = np.random.default_rng(31)
    common = rng.integers(1, cfg.vocab, size=48).astype(np.int32)
    eng = Engine(cfg, PAR, params, n_slots=1, max_seq=128, paged=True,
                 page_size=ps, cache_dtype=jnp.float32,
                 chunked_prefill=True, prefill_chunk=chunk,
                 prefix_sharing=True, prefix_retain_pages=8)
    tail_a = rng.integers(1, cfg.vocab, size=6).astype(np.int32)
    ra = eng.submit(np.concatenate([common, tail_a]), max_new=4)
    eng.run()
    calls_a = eng.backend.prefill_chunk_calls
    assert calls_a == -(-54 // chunk)            # 4 chunks, no sharing yet
    # same-prefix follower: the 6 shared pages cover chunks 1-3 whole;
    # only the tail chunk may run
    tail_b = rng.integers(1, cfg.vocab, size=3).astype(np.int32)
    rb = eng.submit(np.concatenate([common, tail_b]), max_new=4)
    eng.run()
    assert ra.done and rb.done
    assert eng.backend.prefill_chunk_calls - calls_a == 1, \
        "fully prefix-shared chunks must execute zero prefill-kernel calls"
    assert eng.metrics.prefill_tokens_skipped == 48
    st = eng.prefix_stats()
    assert st["hits"] >= 1 and st["cow_copies"] == 0


def test_cohort_catches_up_mid_prefill(subject):
    """Peers admitted in the SAME tick adopt pages a faster peer
    registered chunk-by-chunk — fewer total kernel calls, identical
    greedy output."""
    cfg, params = subject
    rng = np.random.default_rng(33)
    common = rng.integers(1, cfg.vocab, size=48).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(
        1, cfg.vocab, size=5).astype(np.int32)]) for _ in range(3)]

    def run(sharing):
        eng = Engine(cfg, PAR, params, n_slots=3, max_seq=128, paged=True,
                     page_size=8, cache_dtype=jnp.float32,
                     chunked_prefill=True, prefill_chunk=16,
                     prefix_sharing=sharing)
        reqs = [eng.submit(p, max_new=5) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs], eng.backend.prefill_chunk_calls

    base, calls0 = run(False)
    shared, calls1 = run(True)
    assert base == shared, "prefix catch-up must not change greedy output"
    assert calls1 < calls0


def test_retention_survives_cohort_and_evicts_under_pressure(subject):
    cfg, params = subject
    rng = np.random.default_rng(41)
    common = rng.integers(1, cfg.vocab, size=32).astype(np.int32)
    eng = Engine(cfg, PAR, params, n_slots=2, max_seq=64, paged=True,
                 page_size=8, pool_pages=16, cache_dtype=jnp.float32,
                 chunked_prefill=True, prefill_chunk=16,
                 prefix_sharing=True, prefix_retain_pages=4)
    r1 = eng.submit(np.concatenate(
        [common, rng.integers(1, cfg.vocab, size=3).astype(np.int32)]),
        max_new=4)
    eng.run()
    assert r1.done
    st = eng.prefix_stats()
    assert st["retained"] == 4              # cap < 4 full common pages
    assert eng.backend.pool.pages_in_use == st["retained"], \
        "retained pages outlive the cohort"
    # straggler hits the retained prefix
    calls = eng.backend.prefill_chunk_calls
    r2 = eng.submit(np.concatenate(
        [common, rng.integers(1, cfg.vocab, size=2).astype(np.int32)]),
        max_new=4)
    eng.run()
    assert r2.done
    assert eng.prefix_stats()["hits"] >= 1
    assert eng.backend.prefill_chunk_calls - calls == 1
    # pressure: fresh full-pool prompts force the retention LRU to yield
    big = [rng.integers(1, cfg.vocab, size=60).astype(np.int32)
           for _ in range(3)]
    reqs = [eng.submit(p, max_new=4) for p in big]
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.prefix_stats()["evictions"] > 0


def test_retention_unit_deepest_first_eviction():
    pool = PagePool(16, 4)
    pc = PrefixCache(pool, retain_pages=16)
    toks = np.arange(12, dtype=np.int32)
    pages = pool.alloc(3)
    pc.register(toks, pages)
    assert pool.refcount(pages[0]) == 2     # owner + retainer
    pool.free(pages)                        # cohort dies; retention holds
    assert all(pool.refcount(p) == 1 for p in pages)
    assert pc.match(toks) == pages          # still hits
    # eviction drops the DEEPEST chunk of the group: the prefix degrades
    # to a shorter match instead of losing its chain head (which would
    # orphan every deeper page while they stayed pinned)
    assert pc.evict_for(1) == 1
    assert pc.match(toks) == pages[:2]
    assert pc.stats().evictions == 1
    # group LRU across prefixes: a fresh, recently-touched prefix
    # survives while the cold one keeps shrinking tail-first
    toks2 = 100 + np.arange(8, dtype=np.int32)
    pages2 = pool.alloc(2)
    pc.register(toks2, pages2)
    pool.free(pages2)
    assert pc.evict_for(1) == 1
    assert pc.match(toks) == pages[:1]      # cold prefix shrank again
    assert pc.match(toks2) == pages2        # hot prefix intact


def test_retention_admission_accounting_no_double_count(subject):
    """Regression: free_pages() counts retained pages as evictable
    headroom AND the shared-page hint used to discount the same pages
    from the head's need — the attach then pinned them, the remaining
    alloc found nothing to evict, and admission crashed on 'must
    reserve prompt pages first'.  The hint must only discount matched
    pages a LIVE request still holds."""
    cfg, params = subject
    rng = np.random.default_rng(55)
    eng = Engine(cfg, PAR, params, n_slots=2, max_seq=64, paged=True,
                 page_size=4, pool_pages=8, cache_dtype=jnp.float32,
                 chunked_prefill=True, prefill_chunk=8,
                 prefix_sharing=True, prefix_retain_pages=8)
    common = rng.integers(1, cfg.vocab, size=16).astype(np.int32)
    a = eng.submit(common, max_new=2)
    eng.run()
    assert a.done and eng.prefix_stats()["retained"] == 4
    # B occupies the 4 free pages and keeps decoding (its growth also
    # exercises pressure eviction against the retained prefix)
    b = eng.submit(rng.integers(1, cfg.vocab, size=13).astype(np.int32),
                   max_new=12)
    # C matches A's retained prefix but needs MORE pages than the pool
    # can supply once the attach pins them — it must wait, not crash
    c = eng.submit(np.concatenate(
        [common, rng.integers(1, cfg.vocab, size=8).astype(np.int32)]),
        max_new=2)
    eng.run()
    assert b.done and c.done


# ---------------------------------------------------------------------------
# Engine validation + scheduler hook
# ---------------------------------------------------------------------------
def test_chunked_engine_validation(subject):
    cfg, params = subject
    with pytest.raises(ValueError, match="requires paged"):
        Engine(cfg, PAR, params, chunked_prefill=True)
    with pytest.raises(ValueError, match="multiple of page_size"):
        Engine(cfg, PAR, params, paged=True, page_size=16,
               chunked_prefill=True, prefill_chunk=24)
    xcfg = registry.get("xlstm-1.3b").reduced()
    xparams = M.init_params(xcfg, PAR, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention-only"):
        Engine(xcfg, PAR, xparams, paged=True, chunked_prefill=True)


def test_scheduler_next_prefill_slot_class_order():
    class R:
        def __init__(self, rid, priority, admit_seq):
            self.rid, self.priority, self.admit_seq = rid, priority, admit_seq
    s = Scheduler()
    pre = {0: R(1, "batch", 1), 1: R(2, "realtime", 3),
           2: R(3, "standard", 2)}
    assert s.next_prefill_slot(pre) == 1         # highest class first
    del pre[1]
    assert s.next_prefill_slot(pre) == 2
    pre[3] = R(4, "standard", 1)
    assert s.next_prefill_slot(pre) == 3         # FCFS within class
    assert s.next_prefill_slot({}) is None
