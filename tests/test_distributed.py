"""Distribution substrate: sharding rules, gradient compression (error
feedback), GPipe pipeline vs sequential oracle, HLO analyzer."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as PS

from repro.distributed.compression import (CompressionConfig, compress,
                                           init_residual)
from repro.distributed.sharding import Rules
from repro.launch.mesh import compat_make_mesh
from repro.models.param import P


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------
def test_rules_basic_mapping():
    r = Rules()
    assert r.spec(("embed", "heads")) == PS(None, "model")
    assert r.spec(("batch", None, None)) == PS(("data",), None, None)
    assert r.spec(("layers", "embed", "ffn")) == PS(None, None, "model")


def test_rules_conflict_resolution():
    """Same mesh axis twice in one spec → later dim degrades to None."""
    r = Rules(ep=True)
    s = r.spec(("experts", "embed", "ffn"))
    assert s == PS("model", None, None)
    r2 = Rules(ep=False)
    assert r2.spec(("experts", "embed", "ffn")) == PS(None, None, "model")


def test_rules_fsdp_and_multipod():
    r = Rules(dp_axes=("pod", "data"), fsdp=True)
    assert r.spec(("embed", "heads")) == PS(("pod", "data"), "model")
    assert r.spec(("batch", None)) == PS(("pod", "data"), None)
    # fsdp + batch in one spec: no double use of data
    assert r.spec(("batch", "embed")) == PS(("pod", "data"), None)


# ---------------------------------------------------------------------------
# Gradient compression — error feedback
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_error_feedback_preserves_signal(kind, rng):
    """Σ_t compressed_t  →  Σ_t g_t : EF residual carries the rounding
    error forward so the long-run average is unbiased."""
    ccfg = CompressionConfig(kind=kind, topk_frac=0.3)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    res = init_residual(g)
    total_sent = jnp.zeros((64,))
    steps = 30
    for _ in range(steps):
        sent, res = compress(g, res, ccfg)
        total_sent = total_sent + sent["w"]
    expect = np.asarray(g["w"]) * steps
    got = np.asarray(total_sent)
    # residual bounded → averages converge
    assert np.abs(got - expect).max() <= np.abs(np.asarray(g["w"])).max() + 1e-3


def test_compression_noop():
    g = {"a": jnp.ones((4,))}
    out, res = compress(g, jnp.zeros(()), CompressionConfig(kind=None))
    assert out is g


def test_compress_handles_tuple_nodes(rng):
    """Param trees contain tuple stage nodes — regression for the
    tuple-leaf tree_map bug."""
    g = {"stages": [(jnp.ones((4,)), jnp.ones((2,)))], "x": jnp.ones((3,))}
    res = init_residual(g)
    out, res2 = compress(g, res, CompressionConfig(kind="int8"))
    assert jax.tree.structure(out) == jax.tree.structure(g)


# ---------------------------------------------------------------------------
# AdamW with tuple-containing trees (same regression class)
# ---------------------------------------------------------------------------
def test_adamw_tuple_tree(rng):
    from repro.optim.adamw import AdamW
    params = {"stages": [(jnp.ones((4,)), jnp.ones((2, 2)))],
              "embed": jnp.ones((3,))}
    grads = jax.tree.map(jnp.ones_like, params)
    opt = AdamW(lr=0.1)
    st = opt.init(params)
    p2, st2 = opt.update(grads, st, params)
    assert jax.tree.structure(p2) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)):
        assert (np.asarray(a) < np.asarray(b)).all()   # moved downhill


# ---------------------------------------------------------------------------
# GPipe pipeline vs sequential oracle (multi-device CPU via shard_map)
# ---------------------------------------------------------------------------
def test_pipeline_matches_sequential(rng):
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")  # dryrun-only env has 512


def test_pipeline_single_stage_oracle(rng):
    """n_stages=1 degenerate ring equals plain application."""
    from repro.distributed.pipeline import pipeline_apply
    mesh = compat_make_mesh((1,), ("stage",))
    w = jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(3, 4, 8)), jnp.float32)

    def block(p, h):
        return jnp.tanh(h @ p)

    out = pipeline_apply(block, w, x, mesh, axis="stage")
    ref = jnp.stack([block(w[0], x[i]) for i in range(3)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# HLO analyzer — the roofline's measurement tool
# ---------------------------------------------------------------------------
def test_hlo_flop_count_scan_vs_unroll():
    """Trip-count-aware FLOPs must match the closed form on a scan that
    XLA's own cost_analysis undercounts."""
    from repro.launch import hlo_analysis as H
    D, L, MB = 64, 5, 3

    def loss(params, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, params)
        return jnp.mean(h ** 2)

    def train(params, xs):
        def micro(acc, x):
            l, g = jax.value_and_grad(loss)(params, x)
            return (acc[0] + l, acc[1] + g), None
        (l, g), _ = jax.lax.scan(micro, (0.0, jnp.zeros_like(params)), xs)
        return l, g

    params = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    xs = jax.ShapeDtypeStruct((MB, 32, D), jnp.float32)
    c = jax.jit(train).lower(params, xs).compile()
    mod = H.module_analysis(c.as_text())
    expect = 2 * 32 * D * D * L * MB * 3       # fwd + dgrad + wgrad
    assert abs(mod["flops"] - expect) / expect < 0.05
    ca = c.cost_analysis()          # dict on new jax, [dict] on 0.4.x
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    xla = float(ca.get("flops", 0.0))
    assert xla < 0.5 * expect                  # XLA's known undercount


def test_hlo_collective_parsing_fixture():
    from repro.launch import hlo_analysis as H
    hlo = """
HloModule test

%region_body (p: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
  ROOT %t = (s32[], f32[16,128]) tuple(%i, %ar)
}

%region_cond (p: (s32[], f32[16,128])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[16,128]) -> f32[16,128] {
  %w = (s32[], f32[16,128]) while(%init), condition=%region_cond, body=%region_body, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[64,128]{1,0} all-gather(%y), replica_groups=[64,4]<=[256], dimensions={0}
  ROOT %gte = f32[16,128] get-tuple-element(%w), index=1
}
"""
    s = H.collective_summary(hlo)
    ar = s["per_kind"]["all-reduce"]
    assert ar["count"] == 7
    assert ar["operand_bytes"] == 7 * 16 * 128 * 4
    ag = s["per_kind"]["all-gather"]
    assert ag["count"] == 1
    assert ag["operand_bytes"] == 64 * 128 * 4 // 4
    assert ag["wire_bytes"] == 64 * 128 * 4 * 3 // 4


def test_roofline_terms():
    from repro.launch.hlo_analysis import roofline_terms
    r = roofline_terms(197e12, 819e9, 0.0)     # 1s compute, 1s memory
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(1.0)
    assert r["dominant"] in ("compute", "memory")
    r2 = roofline_terms(1e12, 1e9, 500e9)
    assert r2["dominant"] == "collective"
    assert r2["compute_fraction"] < 1.0
