"""Event-loop serving core: typed events, cancellation, ref-counted
copy-on-write prefix sharing, and priority-class scheduling.

The acceptance surface of the tick-engine refactor:

  * refcounts never go negative; fork + release ordering is safe under
    preemption-style interleavings (shared pages survive their donor,
    the pool drains to zero at the end);
  * f32 greedy decode is bit-identical shared-vs-unshared prefix, and
    the common pages of N same-prompt requests are allocated once
    (pool accounting asserted);
  * Engine.cancel frees an in-flight request's pages within one tick
    (queued cancel and queued-deadline expiry hold no pages to leak);
  * under sustained high-priority load, low-priority requests still
    complete (weighted-deficit admission with aging), and victim
    selection evicts the lowest class first.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import model as M
from repro.models.common import Parallel
from repro.runtime.engine import Engine
from repro.runtime.events import (EventBus, ExpireEvent, FinishEvent,
                                  PreemptEvent, TokenEvent)
from repro.runtime.metrics import EngineMetrics
from repro.runtime.paged_cache import (BlockTables, PagePool, PrefixCache,
                                       pages_for_tokens)
from repro.runtime.scheduler import Scheduler, SchedulerConfig

PAR = Parallel(remat=False, attn_chunk=32)


@pytest.fixture(scope="module")
def subject():
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    return cfg, params


def _to_f32(tree):
    return jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        tree)


def make_engine(subject, *, n_slots=2, max_seq=64, **kw):
    cfg, params = subject
    return Engine(cfg, PAR, params, n_slots=n_slots, max_seq=max_seq,
                  prefill_buckets=(16, 32), paged=True, page_size=8, **kw)


# ---------------------------------------------------------------------------
# Refcounted pool + fork/COW block tables
# ---------------------------------------------------------------------------
def test_pool_refcounts_incref_decref():
    pool = PagePool(num_pages=4, page_size=8)
    a = pool.alloc(2)
    assert [pool.refcount(p) for p in a] == [1, 1]
    pool.incref(a)
    assert [pool.refcount(p) for p in a] == [2, 2]
    assert pool.free(a) == 0                # still held once each
    assert pool.pages_in_use == 2
    gen0 = [pool.generation(p) for p in a]
    assert pool.free(a) == 2                # last holder: really freed
    assert pool.pages_in_use == 0
    assert [pool.generation(p) for p in a] == [g + 1 for g in gen0]
    with pytest.raises(ValueError):         # refcounts never go negative
        pool.free(a[:1])
    with pytest.raises(ValueError):
        pool.incref([a[0]])                 # can't attach to a dead page


def test_fork_release_ordering_under_preemption():
    """Donor preempted (released) before/after the sharer, in both
    orders: shared pages survive any living holder and the pool drains
    to exactly zero — no leak, no double free, no negative refcount."""
    for donor_first in (True, False):
        pool = PagePool(num_pages=8, page_size=8)
        bt = BlockTables(pool, n_slots=2, max_blocks=4)
        assert bt.ensure_blocks(0, 3)                 # donor owns 3
        donor_pages = bt.owned(0)
        bt.fork(1, donor_pages[:2])                   # sharer attaches 2
        assert bt.ensure_blocks(1, 3)                 # + 1 private page
        assert pool.pages_in_use == 4
        first, second = (0, 1) if donor_first else (1, 0)
        freed1 = bt.release(first)
        # whoever releases first only really frees their exclusive pages
        assert freed1 == 1
        assert pool.pages_in_use == 3
        freed2 = bt.release(second)
        assert freed2 == 3
        assert pool.pages_in_use == 0
        assert (bt.as_array() == -1).all()


def test_fork_cow_on_write():
    """A write landing in a shared block copies first: private page
    allocated, (src, dst) device copy queued, donor's refcount drops
    back, table repointed, and the splice write-mask clears."""
    pool = PagePool(num_pages=8, page_size=8)
    bt = BlockTables(pool, n_slots=2, max_blocks=4)
    assert bt.ensure_blocks(0, 2)
    donor = bt.owned(0)
    bt.fork(1, donor)
    assert bt.shared_blocks(1) == {0, 1}
    # shared blocks are masked out of splice writes for the sharer...
    assert (bt.writable_row(1) == -1).all()
    # ...and for the DONOR too while someone else holds them (a resume
    # re-splice must not rewrite pages a sharer is attending)
    assert (bt.writable_row(0) == -1).all()
    assert bt.ensure_for_position(1, 12)    # write into shared block 1
    copies = bt.drain_copies()
    assert len(copies) == 1 and copies[0][0] == donor[1]
    assert bt.as_array()[1, 1] == copies[0][1] != donor[1]
    assert pool.refcount(donor[1]) == 1     # back to the donor alone
    assert bt.shared_blocks(1) == {0}
    assert bt.cow_copies == 1
    # block 0 still shared: donor row stays masked there
    assert bt.writable_row(0)[0] == -1 and bt.writable_row(0)[1] != -1
    bt.release(0)
    bt.release(1)
    assert pool.pages_in_use == 0


def test_cow_failure_leaves_consistent_state():
    pool = PagePool(num_pages=2, page_size=8)
    bt = BlockTables(pool, n_slots=2, max_blocks=2)
    assert bt.ensure_blocks(0, 2)
    bt.fork(1, bt.owned(0))
    # pool is empty: the COW copy cannot allocate — refused, shared
    # attach intact, no pending copy
    assert not bt.ensure_for_position(1, 3)
    assert bt.drain_copies() == []
    assert bt.shared_blocks(1) == {0, 1}
    assert pool.refcount(bt.owned(0)[0]) == 2


def test_copy_pages_device_semantics(subject):
    """The COW device copy: pool[dst] = pool[src] across every layer of
    every attention stack; recurrent state untouched."""
    cfg, _ = subject
    caches = M.init_paged_caches(cfg, PAR, 2, 6, 8)
    from repro.models.param import materialize
    caches = materialize(caches, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    caches = jax.tree.map(
        lambda a: jnp.asarray(rng.normal(size=a.shape), a.dtype)
        if a.ndim >= 4 else a, caches)
    out = M.copy_pages(cfg, caches, jnp.asarray([0, 2], jnp.int32),
                       jnp.asarray([4, 5], jnp.int32))
    for stage_in, stage_out in zip(caches, out):
        for pool_in, pool_out in zip(stage_in, stage_out):
            if isinstance(pool_in, dict) and "k" in pool_in \
                    and pool_in["k"].ndim == 5:
                for key in ("k", "v"):
                    np.testing.assert_array_equal(
                        pool_out[key][:, 4], pool_in[key][:, 0])
                    np.testing.assert_array_equal(
                        pool_out[key][:, 5], pool_in[key][:, 2])
                    np.testing.assert_array_equal(   # others untouched
                        pool_out[key][:, :4], pool_in[key][:, :4])


# ---------------------------------------------------------------------------
# Prefix cache registry
# ---------------------------------------------------------------------------
def test_prefix_cache_match_register_stale():
    pool = PagePool(num_pages=8, page_size=4)
    pc = PrefixCache(pool)
    toks = np.arange(1, 11, dtype=np.int32)       # 10 tokens: 2 full pages
    pages = pool.alloc(3)                         # incl. the partial page
    assert pc.register(toks, pages) == 2          # partial chunk excluded
    assert pc.match(toks) == pages[:2]
    # longest-prefix semantics: divergence in chunk 2 keeps chunk 1
    other = toks.copy()
    other[5] = 99
    assert pc.match(other) == pages[:1]
    # different first chunk: no match at all
    assert pc.match(other[::-1]) == []
    # freeing the pages (generation bump) invalidates entries lazily
    pool.free(pages)
    reused = pool.alloc(3)
    assert reused is not None
    assert pc.match(toks) == []
    assert pc.stats().entries < 2                 # stale entry pruned


def test_prefix_cache_registry_stays_bounded():
    """Dead entries are swept once the registry outgrows its pool-sized
    bound — serving diverse prompts forever cannot leak host memory."""
    pool = PagePool(num_pages=16, page_size=4)
    pc = PrefixCache(pool)
    for i in range(200):                          # 200 distinct prompts
        toks = np.arange(4, dtype=np.int32) + 1000 * i
        pages = pool.alloc(1)
        pc.register(toks, pages)
        pool.free(pages)                          # request finished
    assert pc.stats().entries <= max(64, 2 * pool.num_pages) + 1


def test_prefix_cache_first_registrant_wins():
    pool = PagePool(num_pages=8, page_size=4)
    pc = PrefixCache(pool)
    toks = np.arange(1, 5, dtype=np.int32)
    a = pool.alloc(1)
    assert pc.register(toks, a) == 1
    b = pool.alloc(1)
    assert pc.register(toks, b) == 0              # live entry kept
    assert pc.match(toks) == a


# ---------------------------------------------------------------------------
# Shared-vs-unshared: bit-identity + pool accounting
# ---------------------------------------------------------------------------
def test_shared_prefix_f32_bit_identical_and_pages_once(subject):
    """The tentpole acceptance: N requests with a common page-aligned
    prompt prefix allocate the common pages ONCE (refcounted attach),
    and f32 greedy outputs are bit-identical to the unshared path —
    sharing is pure memory dedup, numerics untouched."""
    cfg, params = subject
    params = _to_f32(params)
    local = np.random.default_rng(3)
    common = local.integers(1, cfg.vocab, size=16).astype(np.int32)  # 2 pages
    prompts = [np.concatenate([common,
                               local.integers(1, cfg.vocab, size=5)
                               .astype(np.int32)]) for _ in range(3)]

    def run(sharing):
        eng = Engine(cfg, PAR, params, n_slots=3, max_seq=64,
                     prefill_buckets=(32,), paged=True, page_size=8,
                     prefix_sharing=sharing, cache_dtype=jnp.float32)
        reqs = [eng.submit(p, max_new=6) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return ([r.out_tokens for r in reqs],
                eng.backend.pool.stats().peak_in_use, eng.prefix_stats())

    toks_u, peak_u, _ = run(False)
    toks_s, peak_s, pstats = run(True)
    assert toks_u == toks_s                       # bit-identical greedy
    # the 2 common pages exist once instead of once per request
    assert pstats["hits"] == 2 and pstats["pages_attached"] == 4
    assert peak_u - peak_s == 4
    assert pstats["cow_copies"] == 0              # full-page-only attach


def test_shared_prefix_survives_donor_finish(subject):
    """Shared pages outlive their donor: the sharer keeps decoding
    against them after the donor finishes and releases (refcount, not
    ownership, decides page lifetime)."""
    cfg, params = subject
    local = np.random.default_rng(5)
    common = local.integers(1, cfg.vocab, size=16).astype(np.int32)
    p_short = np.concatenate([common,
                              local.integers(1, cfg.vocab, size=3)
                              .astype(np.int32)])
    p_long = np.concatenate([common,
                             local.integers(1, cfg.vocab, size=4)
                             .astype(np.int32)])
    eng = make_engine(subject, prefix_sharing=True)
    r_short = eng.submit(p_short, max_new=2)      # donor finishes first
    r_long = eng.submit(p_long, max_new=20)
    eng.run()
    assert r_short.done and r_long.done
    assert len(r_long.out_tokens) == 20
    assert eng.prefix_stats()["pages_attached"] == 2
    assert eng.backend.pool.pages_in_use == 0     # full drain, no leak


def test_shared_prefix_with_preemption_completes(subject):
    """Sharing + tight pool: preemption releases shared references
    safely (the donor's resume re-splice is masked off pages a sharer
    holds) and every request completes with its full token budget."""
    cfg, params = subject
    local = np.random.default_rng(9)
    common = local.integers(1, cfg.vocab, size=16).astype(np.int32)
    prompts = [np.concatenate([common,
                               local.integers(1, cfg.vocab, size=4 + i)
                               .astype(np.int32)]) for i in range(3)]
    eng = make_engine(subject, prefix_sharing=True, pool_pages=7)
    reqs = [eng.submit(p, max_new=16) for p in prompts]
    eng.run()
    assert all(r.done and len(r.out_tokens) == 16 for r in reqs)
    assert eng.metrics.snapshot()["preemptions"] >= 1
    assert eng.backend.pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# Events + cancellation
# ---------------------------------------------------------------------------
def test_event_stream_matches_outputs(subject, rng):
    cfg, _ = subject
    eng = make_engine(subject)
    q = eng.event_queue()
    reqs = [eng.submit(rng.integers(1, cfg.vocab, size=n).astype(np.int32),
                       max_new=4) for n in (5, 9, 12)]
    eng.run()
    toks, finishes = {}, {}
    while q:
        ev = q.popleft()
        if isinstance(ev, TokenEvent):
            assert ev.index == len(toks.setdefault(ev.rid, []))
            toks[ev.rid].append(ev.token)
        elif isinstance(ev, FinishEvent):
            finishes[ev.rid] = ev
    for r in reqs:
        assert toks[r.rid] == r.out_tokens        # stream == final output
        assert finishes[r.rid].reason == "max_new"
        assert finishes[r.rid].n_tokens == 4
    # every page allocated over the run came back through releases
    assert sum(f.freed_pages for f in finishes.values()) > 0
    assert eng.backend.pool.pages_in_use == 0


def test_preempt_and_expire_events(subject, rng):
    cfg, _ = subject
    seen = []
    eng = make_engine(subject, pool_pages=6)
    eng.subscribe(seen.append)
    a = eng.submit(rng.integers(1, cfg.vocab, size=13).astype(np.int32),
                   max_new=20)
    b = eng.submit(rng.integers(1, cfg.vocab, size=13).astype(np.int32),
                   max_new=20)
    c = eng.submit(rng.integers(1, cfg.vocab, size=8).astype(np.int32),
                   max_new=4, deadline_s=0.0)     # expires while queued
    eng.run()
    assert a.done and b.done and c.expired
    pre = [e for e in seen if isinstance(e, PreemptEvent)]
    exp = [e for e in seen if isinstance(e, ExpireEvent)]
    assert len(pre) >= 1 and pre[0].freed_pages > 0
    assert [e.rid for e in exp] == [c.rid]


def test_cancel_running_frees_pages_same_tick(subject, rng):
    cfg, _ = subject
    eng = make_engine(subject)
    a = eng.submit(rng.integers(1, cfg.vocab, size=9).astype(np.int32),
                   max_new=30)
    b = eng.submit(rng.integers(1, cfg.vocab, size=9).astype(np.int32),
                   max_new=6)
    q = eng.event_queue()
    for _ in range(3):
        eng.tick()
    in_use = eng.backend.pool.pages_in_use
    held = eng.backend.tables.n_blocks(0)
    assert held > 0
    assert eng.cancel(a.rid)                      # outside tick: immediate
    assert a.cancelled and a.done
    assert eng.backend.pool.pages_in_use == in_use - held
    fin = [e for e in q if isinstance(e, FinishEvent)]
    assert fin and fin[-1].reason == "cancelled"
    assert fin[-1].freed_pages == held
    assert not eng.cancel(a.rid)                  # already finished
    eng.run()                                     # others unaffected
    assert b.done and len(b.out_tokens) == 6
    assert eng.metrics.snapshot()["cancellations"] == 1


def test_cancel_queued_request(subject, rng):
    cfg, _ = subject
    eng = make_engine(subject, n_slots=1)
    a = eng.submit(rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                   max_new=8)
    b = eng.submit(rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                   max_new=8)
    assert eng.cancel(b.rid)                      # still queued: no pages
    eng.run()
    assert a.done and len(a.out_tokens) == 8
    assert b.cancelled and b.out_tokens == []
    assert eng.metrics.snapshot()["completed"] == 1


def test_cancel_from_event_callback_same_tick(subject, rng):
    """Cancel issued from inside a token callback is deferred to the
    end of the SAME tick: pages free before the next tick begins."""
    cfg, _ = subject
    eng = make_engine(subject)
    r = eng.submit(rng.integers(1, cfg.vocab, size=9).astype(np.int32),
                   max_new=30)
    cancel_tick = []

    @eng.subscribe
    def _cb(ev):
        if isinstance(ev, TokenEvent) and ev.rid == r.rid and ev.index == 2:
            eng.cancel(r.rid)
            cancel_tick.append(ev.tick)
        if isinstance(ev, FinishEvent) and ev.rid == r.rid:
            assert ev.reason == "cancelled"
            assert ev.tick == cancel_tick[0]      # same tick
    eng.run()
    assert r.cancelled and len(r.out_tokens) == 3
    assert eng.backend.pool.pages_in_use == 0
    assert cancel_tick


def test_cancel_unknown_rid(subject):
    eng = make_engine(subject)
    assert not eng.cancel(12345)


def test_request_registry_drains_and_rejections_not_retained(subject, rng):
    """The rid->Request registry only holds live requests: finished /
    cancelled / expired entries drop, and a submit rejected for pool
    size never registers (cancel of its rid is a no-op, not a spurious
    FinishEvent)."""
    cfg, _ = subject
    eng = make_engine(subject, pool_pages=4)
    done = eng.submit(rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                      max_new=2)
    with pytest.raises(ValueError):
        eng.submit(rng.integers(1, cfg.vocab, size=20).astype(np.int32),
                   max_new=30)
    rejected_rid = done.rid + 1
    assert not eng.cancel(rejected_rid)
    eng.run()
    assert done.done
    assert eng._requests == {}                    # nothing retained


# ---------------------------------------------------------------------------
# Priority classes: WDRR shares, aging, class-aware victims
# ---------------------------------------------------------------------------
class _Req:
    def __init__(self, rid, priority="standard", need_toks=8):
        self.rid, self.priority, self.admit_seq = rid, priority, 0
        self.deadline_t = None
        self._need = need_toks

    def n_prompt_tokens(self):
        return self._need


def test_wdrr_service_shares():
    """Backlogged realtime (w=8) vs batch (w=1): admissions interleave
    at roughly the weight ratio instead of starving batch."""
    s = Scheduler(clock=lambda: 0.0)              # aging off: pure WDRR
    for i in range(16):
        s.enqueue(_Req(i, "realtime"))
    for i in range(16, 20):
        s.enqueue(_Req(i, "batch"))
    order = [s.next_admissible(None, 8).priority for _ in range(20)]
    # batch admissions land mid-stream at ~1 per 9 (weights 8:1), NOT
    # after the realtime queue drains — and everyone is served
    batch_at = [i for i, c in enumerate(order) if c == "batch"]
    assert len(batch_at) == 4 and order.count("realtime") == 16
    assert batch_at[0] <= 8                       # first share arrives early
    assert batch_at[1] < 16                       # interleaved, not tailed


def test_aging_bounds_low_priority_wait():
    """A long-waiting batch head outscores fresh realtime arrivals once
    aging_rate * wait exceeds the weight gap."""
    t = [0.0]
    s = Scheduler(SchedulerConfig(aging_rate=1.0), clock=lambda: t[0])
    s.enqueue(_Req(1, "batch"))
    t[0] = 100.0                                  # batch waited 100s
    s.enqueue(_Req(2, "realtime"))
    got = s.next_admissible(None, 8)
    assert got.rid == 1                           # age trumps weight


def test_victims_evict_lowest_class_first():
    s = Scheduler()
    running = {0: _Req(1, "realtime"), 1: _Req(2, "batch"),
               2: _Req(3, "batch")}
    for slot, r in running.items():
        r.admit_seq = slot + 1
    assert s.choose_victim(running) == 2          # newest IN batch
    s_old = Scheduler(SchedulerConfig(preempt_policy="oldest"))
    assert s_old.choose_victim(running) == 1
    # exclude still respected inside the class filter
    assert s.choose_victim(running, exclude=2) == 1


def test_unknown_priority_rejected(subject, rng):
    cfg, _ = subject
    s = Scheduler()
    with pytest.raises(ValueError):
        s.enqueue(_Req(1, "vip"))
    eng = make_engine(subject)
    with pytest.raises(ValueError):
        eng.submit(rng.integers(1, cfg.vocab, size=4).astype(np.int32),
                   priority="vip")


def test_starvation_bounded_under_high_priority_load(subject, rng):
    """The acceptance starvation test: one slot, a stream of realtime
    requests ahead of and behind a single batch request — the batch
    request is admitted within the WDRR share bound and completes."""
    cfg, _ = subject
    eng = make_engine(subject, n_slots=1,
                      scheduler=Scheduler(clock=lambda: 0.0))
    hi = [eng.submit(rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                     max_new=3, priority="realtime") for _ in range(9)]
    lo = eng.submit(rng.integers(1, cfg.vocab, size=6).astype(np.int32),
                    max_new=3, priority="batch")
    eng.run()
    assert lo.done and len(lo.out_tokens) == 3
    assert all(r.done for r in hi)
    # admitted mid-stream (weight ratio 8:4:1 -> within ~half the
    # realtime backlog), not after the realtime queue drained
    assert lo.admit_seq <= 7
    pc = eng.metrics.snapshot()["per_class"]
    assert pc["batch"]["completed"] == 1
    assert pc["realtime"]["completed"] == 9


# ---------------------------------------------------------------------------
# TBT metrics
# ---------------------------------------------------------------------------
def test_tbt_per_request_and_class():
    m = EngineMetrics(clock=iter(np.arange(0.0, 100.0, 0.5)).__next__)
    m.on_submit(1, "realtime")
    m.on_submit(2, "batch")
    for _ in range(4):
        m.on_token(1)
    m.on_token(2)
    m.on_finish(1)
    m.on_finish(2)
    snap = m.snapshot()
    assert snap["tbt_p50_s"] > 0                  # 3 gaps from rid 1
    assert snap["tbt_p95_s"] >= snap["tbt_p50_s"]
    assert snap["per_class"]["realtime"]["tbt_p50_s"] > 0
    assert snap["per_class"]["batch"]["tbt_p50_s"] == 0.0  # single token
    assert snap["per_class"]["realtime"]["generated_tokens"] == 4


def test_tbt_excludes_compile_stalls():
    """A gap spanning on_stall() (jit compile) never enters the TBT
    series — tbt_p95 describes steady-state decode, not warmup."""
    m = EngineMetrics(clock=iter(np.arange(0.0, 100.0, 0.5)).__next__)
    m.on_submit(1)
    m.on_token(1)
    m.on_token(1)               # gap 1: clean
    m.on_stall()
    m.on_token(1)               # gap 2: spans the stall -> dropped
    m.on_token(1)               # gap 3: clean again
    t = m._req[1]
    assert len(t.tbt) == 2


def test_preemption_requeue_keeps_aging_clock():
    """A preemption victim re-enqueued at the front keeps its original
    enqueue stamp: its aging accumulates across admit->preempt cycles
    instead of resetting to zero each round."""
    t = [0.0]
    s = Scheduler(clock=lambda: t[0])
    r = _Req(1, "batch")
    s.enqueue(r)
    stamp = r.enqueue_t
    got = s.next_admissible(None, 8)
    assert got is r
    t[0] = 50.0
    s.enqueue(r, front=True)                      # preempted, re-queued
    assert r.enqueue_t == stamp                   # clock not reset
    r2 = _Req(2, "standard")
    t[0] = 51.0
    s.enqueue(r2)
    assert r2.enqueue_t == 51.0                   # fresh requests stamp


def test_engine_tbt_observable(subject, rng):
    cfg, _ = subject
    eng = make_engine(subject)
    r = eng.submit(rng.integers(1, cfg.vocab, size=9).astype(np.int32),
                   max_new=8, priority="realtime")
    eng.run()
    assert r.done
    snap = eng.metrics.snapshot()
    assert snap["tbt_p50_s"] > 0
    assert snap["per_class"]["realtime"]["requests"] == 1


# ---------------------------------------------------------------------------
# Event bus
# ---------------------------------------------------------------------------
def test_event_bus_queue_and_unsubscribe():
    bus = EventBus()
    q = bus.queue(maxlen=2)
    seen = []
    cb = bus.subscribe(seen.append)
    for i in range(3):
        bus.publish(TokenEvent(1, i, i, 0))
    assert len(seen) == 3
    assert [e.token for e in q] == [1, 2]         # maxlen drops oldest
    bus.unsubscribe(cb)
    bus.publish(TokenEvent(1, 9, 3, 0))
    assert len(seen) == 3
    # a queue subscriber detaches via its (fresh-per-access) bound
    # append — equality, not identity, must decide
    bus.unsubscribe(q.append)
    bus.publish(TokenEvent(1, 10, 4, 0))
    assert [e.token for e in q] == [2, 9]         # nothing new appended
