"""Core PTQ1.61 behaviour: calibrated pipeline, block-wise optimization,
bit accounting, preprocessing — on a tiny model (fast CPU scale)."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import blockwise
from repro.core.bits import model_bits, paper_closed_form, qlinear_bits
from repro.core.pipeline import (quantize_model_ptq161,
                                 quantize_params_data_free)
from repro.core.preprocess import PreprocessConfig, restorative_lora
from repro.core.qlinear import QLinear, QuantConfig, quantize_linear
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import model as M
from repro.models.common import Parallel

PAR = Parallel(remat=False, attn_chunk=64)


@pytest.fixture(scope="module")
def tiny():
    cfg = registry.get("tiny-lm").reduced()
    params = M.init_params(cfg, PAR, jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab))
    return cfg, params, corpus


def eval_loss(cfg, params, corpus, n=2):
    tot = 0.0
    for tok, tgt in corpus.batches(4, 64, n, split="valid"):
        tot += float(M.forward_loss(cfg, PAR, params, {
            "tokens": jnp.asarray(tok), "targets": jnp.asarray(tgt)}))
    return tot / n


def test_appendix_a_bit_accounting():
    """The paper's worked example: 4096×4096, 20% salient → ≈1.61 b/w."""
    rep = paper_closed_form(4096, 4096, 0.2)
    # int(0.2·4096)=819 (not 819.2) → weight bits 1.59985, matching the
    # paper's own rounding to 1.6
    assert abs(rep.weight_bits - 1.6) < 1e-3
    assert abs(rep.index_bits - 0.000244) < 1e-4
    # scales+zeros: (2N + k_b + 2k_s)·16/(K·N) = 0.0125 b/w — the paper
    # reports 0.008 by dividing by its bit total rather than the weight
    # count; we keep the per-weight denominator (stricter)
    assert rep.additional_bits < 0.02
    assert 1.60 < rep.total_bits < 1.62


def test_qlinear_bits_match_closed_form(rng):
    w = jnp.asarray(rng.normal(size=(4096, 128)) * 0.02, jnp.float32)
    q = quantize_linear(w, None, QuantConfig(ratio=0.2, multiple=128))
    rep = qlinear_bits(q)
    assert abs(rep.weight_bits - 1.6) < 0.05
    assert rep.total_bits < 1.75   # small N inflates per-col scale share


def test_packed_storage_is_sub2bit(rng):
    """Actual packed bytes of a QLinear ≤ 2.0 bits/weight equivalent."""
    k, n = 2048, 512
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.02, jnp.float32)
    q = quantize_linear(w, None, QuantConfig(ratio=0.2, multiple=128))
    bits_per_w = 8.0 * q.packed_bytes() / (k * n)
    # perm (int32) is derivable from the 1-bit mask at load time; exclude
    bits_per_w -= 8.0 * q.perm.size * 4 / (k * n)
    assert bits_per_w < 2.0, bits_per_w


def test_calibrated_pipeline_beats_data_free(tiny):
    """Learnable scales (Eq. 7) must not be worse than analytic init on
    the calibration distribution (paper Table 3 rows 2 vs 4).

    Margin: XLA CPU numerics vary ACROSS processes (compile-time thread
    partitioning of reductions), which moves both losses by up to ~0.15
    on this 4-step tiny subject — measured spreads l_learn 6.62–6.88 /
    l_free 6.75–6.80 over repeated identical runs.  The old 0.05 margin
    sat inside that noise and flaked ~1 run in 6; 0.3 stays well below
    any real regression (a broken optimizer lands > +1)."""
    cfg, params, corpus = tiny
    calib = [{"tokens": jnp.asarray(t)} for t, _ in
             corpus.batches(2, 64, 3, split="calib")]
    qcfg = QuantConfig(ratio=0.2, multiple=16, steps=4)
    q_learn = quantize_model_ptq161(cfg, PAR, params, calib, qcfg,
                                    min_dim=32)
    q_free = quantize_params_data_free(
        params, dataclasses.replace(qcfg, learn_scales=False), min_dim=32)
    l_learn = eval_loss(cfg, q_learn, corpus)
    l_free = eval_loss(cfg, q_free, corpus)
    assert np.isfinite(l_learn) and np.isfinite(l_free)
    assert l_learn <= l_free + 0.3, (l_learn, l_free)


def test_blockwise_metric_properties(rng):
    f = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    assert float(blockwise.metric(f, f)) < 1e-5          # identity ≈ 0
    g = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    m = float(blockwise.metric(f, g))
    assert m > 0
    # cosine term penalizes angular error beyond pure MSE
    m_nocos = float(blockwise.metric(f, g, cosine=False))
    assert m >= m_nocos


def test_blockwise_optimization_reduces_block_error(tiny, rng):
    """Eq. 7 objective decreases on the block it optimizes."""
    cfg, params, _ = tiny
    from repro.core.pipeline import _block_forward, tree_slice
    fp_block = tree_slice(params["stages"][0][0], 0)
    fwd = _block_forward(cfg, PAR, "dense")
    x = [jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)) * 0.3,
                     jnp.bfloat16) for _ in range(2)]

    def qblockify(qcfg):
        from repro.core.select import map_quantizable
        return map_quantizable(
            fp_block, lambda p, w: quantize_linear(w, None, qcfg),
            min_dim=32)

    def obj(qb):
        tot = 0.0
        for xi in x:
            y = fwd(fp_block, xi)
            yq = fwd(qb, xi)
            tot += float(blockwise.metric(y, yq))
        return tot

    q0 = qblockify(QuantConfig(ratio=0.25, multiple=16, steps=0))
    before = obj(q0)
    q1 = blockwise.optimize_block_scales(
        fwd, fp_block, q0, x, x, QuantConfig(ratio=0.25, multiple=16,
                                             steps=6))
    after = obj(q1)
    assert after <= before + 1e-6, (before, after)


def test_preprocess_returns_full_precision_tree(tiny):
    """Restorative LoRA merges into FP weights — same tree structure,
    same shapes/dtypes, no QLinear leaves (paper §3.4: nothing extra
    ships at inference)."""
    cfg, params, corpus = tiny
    batches = [{"tokens": jnp.asarray(t), "targets": jnp.asarray(g)}
               for t, g in corpus.batches(2, 32, 2, split="calib")]
    pp = restorative_lora(cfg, PAR, params, batches,
                          QuantConfig(ratio=0.2, multiple=16),
                          PreprocessConfig(rank=4, steps=4, lr=1e-4),
                          min_dim=32)
    assert jax.tree.structure(pp) == jax.tree.structure(params)
    changed = 0
    for a, b in zip(jax.tree.leaves(pp), jax.tree.leaves(params)):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert not isinstance(a, QLinear)
        if np.abs(np.asarray(a, np.float32) -
                  np.asarray(b, np.float32)).max() > 1e-6:
            changed += 1
    assert changed > 0, "preprocessing changed no weights"


def test_model_bits_aggregate(tiny):
    cfg, params, _ = tiny
    qp = quantize_params_data_free(params,
                                   QuantConfig(ratio=0.2, multiple=16),
                                   min_dim=32)
    rep = model_bits(qp)
    assert rep["quantized_weights"] > 0
    assert rep["avg_bits_per_quantized_weight"] < 4.0
    assert 0 < rep["exempt_fraction"] < 1
